//! Deterministic scoped-thread parallel map — the worker machinery shared
//! by the sweep harness and the portfolio solver.
//!
//! The build is vendored-deps-only (no rayon): workers are plain
//! `std::thread::scope` threads pulling item indices off a shared atomic
//! cursor. Results land in a slot vector **by item index**, so the output
//! order — and, because every `f(i, item)` call is required to be a pure
//! deterministic function of its inputs, the output *bytes* — are
//! independent of the thread count and of work-stealing order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Apply `f` to every item of `items` across up to `threads` scoped worker
/// threads; returns the results in item order.
///
/// `threads <= 1` (or a single item) runs inline on the caller's thread
/// with no spawn overhead — the hot path for nested uses (a solver lane
/// inside a sweep worker). `f` must not depend on execution order: it is
/// called exactly once per item, from an arbitrary worker.
pub fn par_map<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<R>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                let Some(item) = items.get(i) else { break };
                let r = f(i, item);
                slots.lock().unwrap()[i] = Some(r);
            });
        }
    });
    slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("a worker ran every item"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_item_order() {
        let items: Vec<usize> = (0..97).collect();
        let serial = par_map(1, &items, |i, &x| (i, x * x));
        let parallel = par_map(8, &items, |i, &x| (i, x * x));
        assert_eq!(serial, parallel);
        for (i, &(j, sq)) in serial.iter().enumerate() {
            assert_eq!(i, j);
            assert_eq!(sq, i * i);
        }
    }

    #[test]
    fn empty_and_single() {
        let none: Vec<u32> = Vec::new();
        assert!(par_map(4, &none, |_, &x| x).is_empty());
        assert_eq!(par_map(4, &[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn oversubscribed_thread_count_is_clamped() {
        let items = [1u32, 2, 3];
        assert_eq!(par_map(64, &items, |_, &x| x * 10), vec![10, 20, 30]);
    }
}
