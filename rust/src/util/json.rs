//! Minimal JSON parser — enough for `artifacts/manifest.json` and for
//! machine-readable experiment outputs. Recursive descent, owned values.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
}

impl fmt::Display for Json {
    /// Compact serialization (used for experiment result dumps).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write!(f, "{}", escape(s)),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", escape(k), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Parse a JSON document. Returns a descriptive error with byte offset.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .ok_or("bad \\u escape")?;
                            out.push(char::from_u32(hex).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a UTF-8 scalar as-is.
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|_| "bad utf8")?;
                    let c = s.chars().next().ok_or("unterminated string")?;
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(parse("-12").unwrap(), Json::Num(-12.0));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        assert_eq!(parse(r#""a\nb\t\"q\" A""#).unwrap(), Json::Str("a\nb\t\"q\" A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{'a':1}").is_err());
    }

    #[test]
    fn display_roundtrip() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn manifest_shape() {
        let src = r#"{"format":"hlo-text","entries":[{"name":"gemm_f32_32","tile":32,"flops":65536.0}]}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("format").unwrap().as_str(), Some("hlo-text"));
        let e = &v.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("tile").unwrap().as_usize(), Some(32));
    }

    #[test]
    fn unicode_passthrough() {
        assert_eq!(parse(r#""héllo""#).unwrap(), Json::Str("héllo".into()));
    }
}
