//! Tiny CLI flag parser (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and free
//! positional arguments. Subcommands are handled by `main.rs` taking the
//! first positional.

use std::collections::BTreeMap;

/// Parsed command line: positionals in order + flag map.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Flags seen, for unknown-flag reporting.
    seen: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                    out.seen.push(k.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap_or_default();
                    out.flags.insert(name.to_string(), v);
                    out.seen.push(name.to_string());
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                    out.seen.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Lowercased value, if the flag was passed — the form every
    /// name-keyed lookup (policies, caching modes, objectives) wants.
    pub fn get_lower(&self, name: &str) -> Option<String> {
        self.get(name).map(|s| s.to_ascii_lowercase())
    }

    /// Lowercased value with a default.
    pub fn str_lower_or(&self, name: &str, default: &str) -> String {
        self.get_lower(name).unwrap_or_else(|| default.to_ascii_lowercase())
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn bool_or(&self, name: &str, default: bool) -> bool {
        self.get(name).map(|s| s == "true" || s == "1" || s == "yes").unwrap_or(default)
    }

    /// Comma-separated list of usize, e.g. `--tiles 128,256,512`.
    pub fn usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            Some(s) => s.split(',').filter_map(|p| p.trim().parse().ok()).collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positionals_and_flags() {
        let a = parse("solve --platform configs/b.toml --iters 200 out.json");
        assert_eq!(a.positional, vec!["solve", "out.json"]);
        assert_eq!(a.get("platform"), Some("configs/b.toml"));
        assert_eq!(a.usize_or("iters", 0), 200);
    }

    #[test]
    fn eq_form_and_bools() {
        let a = parse("run --n=4096 --verbose --last");
        assert_eq!(a.usize_or("n", 0), 4096);
        assert!(a.bool_or("verbose", false));
        assert!(a.has("last"));
        assert!(!a.has("missing"));
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse("x --tiles 128,256,512");
        assert_eq!(a.usize_list("tiles", &[64]), vec![128, 256, 512]);
        assert_eq!(a.usize_list("other", &[64]), vec![64]);
        assert_eq!(a.f64_or("gamma", 1.5), 1.5);
        assert_eq!(a.str_or("mode", "sim"), "sim");
    }

    #[test]
    fn lowercased_lookups() {
        let a = parse("x --policy PL/EFT-P");
        assert_eq!(a.get_lower("policy").as_deref(), Some("pl/eft-p"));
        assert_eq!(a.get_lower("missing"), None);
        assert_eq!(a.str_lower_or("policy", "fcfs/r-p"), "pl/eft-p");
        assert_eq!(a.str_lower_or("missing", "FCFS/R-P"), "fcfs/r-p");
    }

    #[test]
    fn negative_number_values() {
        // A value starting with '-' but not '--' is consumed as a value.
        let a = parse("x --offset -3");
        assert_eq!(a.get("offset"), Some("-3"));
    }
}
