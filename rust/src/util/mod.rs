//! Small self-contained substrates that replace ecosystem crates
//! (the build is fully offline — see Cargo.toml): a seeded PRNG, a JSON
//! parser for the artifact manifest, a TOML-subset parser for platform
//! configs, and a tiny CLI flag parser.

pub mod cli;
pub mod fxhash;
pub mod json;
pub mod rng;
pub mod toml;
