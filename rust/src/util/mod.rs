//! Small self-contained substrates that replace ecosystem crates
//! (the build is fully offline — see Cargo.toml): a seeded PRNG, a JSON
//! parser for the artifact manifest, a TOML-subset parser for platform
//! configs, a tiny CLI flag parser, shared descriptive statistics
//! (percentiles, Jain fairness), and the deterministic scoped-thread
//! parallel map the sweep harness and portfolio solver share.

pub mod cli;
pub mod fxhash;
pub mod json;
pub mod par;
pub mod rng;
pub mod stats;
pub mod toml;
