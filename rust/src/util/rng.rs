//! SplitMix64 PRNG: all stochastic choices in HeSP (R-P processor
//! selection, Soft candidate sampling, synthetic workload generation) go
//! through this seeded generator so every experiment is reproducible.

/// SplitMix64 — tiny, fast, and statistically fine for simulation choices.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Multiply-shift; bias is negligible for simulation-sized n.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Pick a uniformly random element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample an index with probability proportional to `weights`
    /// (non-negative, not all zero). Used by the Soft candidate selection.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted sample over zero-total weights");
        let mut u = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Standard normal via Box–Muller (for synthetic workloads).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn below_covers_all_values() {
        let mut r = Rng::new(3);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5, "counts={counts:?}");
    }

    #[test]
    fn weighted_single() {
        let mut r = Rng::new(6);
        assert_eq!(r.weighted(&[3.0]), 0);
    }

    #[test]
    #[should_panic]
    fn weighted_zero_total_panics() {
        Rng::new(1).weighted(&[0.0, 0.0]);
    }

    #[test]
    fn normal_mean_and_var_sane() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }
}
