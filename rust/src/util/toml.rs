//! TOML-subset parser for platform/experiment configs (`configs/*.toml`).
//!
//! Supported: `[table]` headers, `[[array-of-tables]]` headers, dotted
//! headers (`[perf.gpu.gemm]`), `key = value` with strings, integers,
//! floats, booleans and homogeneous arrays, `#` comments. This covers the
//! full config schema in `configs/`; anything fancier is a parse error,
//! not silent misbehaviour.

use std::collections::BTreeMap;

/// A TOML value (subset).
#[derive(Debug, Clone, PartialEq)]
pub enum Toml {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Toml>),
    Table(BTreeMap<String, Toml>),
    /// Array of tables, from `[[name]]` sections.
    TableArr(Vec<BTreeMap<String, Toml>>),
}

impl Toml {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Toml::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Toml::Int(i) => Some(*i as f64),
            Toml::Float(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Toml::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Toml::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Toml]> {
        match self {
            Toml::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_table(&self) -> Option<&BTreeMap<String, Toml>> {
        match self {
            Toml::Table(t) => Some(t),
            _ => None,
        }
    }
    pub fn as_table_arr(&self) -> Option<&[BTreeMap<String, Toml>]> {
        match self {
            Toml::TableArr(v) => Some(v),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Toml> {
        self.as_table().and_then(|t| t.get(key))
    }
    /// Navigate a dotted path, e.g. `get_path("perf.gpu.gemm")`.
    pub fn get_path(&self, path: &str) -> Option<&Toml> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }
}

/// Parse a TOML-subset document into a root table.
pub fn parse(input: &str) -> Result<Toml, String> {
    let mut root: BTreeMap<String, Toml> = BTreeMap::new();
    // Path of the currently open table ([] = root); `true` if the last
    // segment addresses the tail of an array-of-tables.
    let mut cur_path: Vec<String> = Vec::new();
    let mut cur_is_arr = false;

    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| format!("config line {}: {msg}: {raw}", lineno + 1);

        if let Some(rest) = line.strip_prefix("[[") {
            let name = rest.strip_suffix("]]").ok_or_else(|| err("bad [[header]]"))?;
            cur_path = name.trim().split('.').map(|s| s.trim().to_string()).collect();
            cur_is_arr = true;
            let (parent, leaf) = open_parent(&mut root, &cur_path)?;
            match parent.entry(leaf.clone()).or_insert_with(|| Toml::TableArr(Vec::new())) {
                Toml::TableArr(v) => v.push(BTreeMap::new()),
                _ => return Err(err("redefined as array-of-tables")),
            }
        } else if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| err("bad [header]"))?;
            cur_path = name.trim().split('.').map(|s| s.trim().to_string()).collect();
            cur_is_arr = false;
            let (parent, leaf) = open_parent(&mut root, &cur_path)?;
            match parent.entry(leaf.clone()).or_insert_with(|| Toml::Table(BTreeMap::new())) {
                Toml::Table(_) => {}
                _ => return Err(err("redefined as table")),
            }
        } else {
            let eq = line.find('=').ok_or_else(|| err("expected key = value"))?;
            let key = line[..eq].trim().trim_matches('"').to_string();
            let val = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
            let table = open_table(&mut root, &cur_path, cur_is_arr)?;
            if table.insert(key, val).is_some() {
                return Err(err("duplicate key"));
            }
        }
    }
    Ok(Toml::Table(root))
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Walk to the parent table of `path`, creating intermediate tables.
fn open_parent<'a>(
    root: &'a mut BTreeMap<String, Toml>,
    path: &[String],
) -> Result<(&'a mut BTreeMap<String, Toml>, String), String> {
    let (leaf, parents) = path.split_last().ok_or("empty header")?;
    let mut cur = root;
    for p in parents {
        let next = cur.entry(p.clone()).or_insert_with(|| Toml::Table(BTreeMap::new()));
        cur = match next {
            Toml::Table(t) => t,
            Toml::TableArr(v) => v.last_mut().ok_or("empty table array")?,
            _ => return Err(format!("'{p}' is not a table")),
        };
    }
    Ok((cur, leaf.clone()))
}

/// Resolve the table currently addressed by `path` for key insertion.
fn open_table<'a>(
    root: &'a mut BTreeMap<String, Toml>,
    path: &[String],
    is_arr: bool,
) -> Result<&'a mut BTreeMap<String, Toml>, String> {
    if path.is_empty() {
        return Ok(root);
    }
    let (parent, leaf) = open_parent(root, path)?;
    match parent.get_mut(&leaf) {
        Some(Toml::Table(t)) if !is_arr => Ok(t),
        Some(Toml::TableArr(v)) if is_arr => v.last_mut().ok_or_else(|| "empty table array".into()),
        _ => Err(format!("header '{leaf}' missing")),
    }
}

fn parse_value(s: &str) -> Result<Toml, String> {
    if let Some(rest) = s.strip_prefix('"') {
        let end = rest.find('"').ok_or("unterminated string")?;
        if !rest[end + 1..].trim().is_empty() {
            return Err("trailing garbage after string".into());
        }
        return Ok(Toml::Str(rest[..end].to_string()));
    }
    if s == "true" {
        return Ok(Toml::Bool(true));
    }
    if s == "false" {
        return Ok(Toml::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Toml::Arr(items));
    }
    let clean = s.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(Toml::Int(i));
    }
    if let Ok(x) = clean.parse::<f64>() {
        return Ok(Toml::Float(x));
    }
    Err(format!("cannot parse value '{s}'"))
}

/// Split on commas not nested inside brackets or strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_comments() {
        let t = parse("a = 1 # comment\nb = 2.5\nc = \"x # not comment\"\nd = true\n").unwrap();
        assert_eq!(t.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(t.get("b").unwrap().as_f64(), Some(2.5));
        assert_eq!(t.get("c").unwrap().as_str(), Some("x # not comment"));
        assert_eq!(t.get("d").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn tables_and_dotted() {
        let t = parse("[perf.gpu.gemm]\npeak = 2000.0\nhalf = 512\n").unwrap();
        assert_eq!(t.get_path("perf.gpu.gemm.peak").unwrap().as_f64(), Some(2000.0));
        assert_eq!(t.get_path("perf.gpu.gemm.half").unwrap().as_i64(), Some(512));
    }

    #[test]
    fn array_of_tables() {
        let src = "[[processor]]\nname = \"cpu0\"\n[[processor]]\nname = \"gpu0\"\nfast = true\n";
        let t = parse(src).unwrap();
        let procs = t.get("processor").unwrap().as_table_arr().unwrap();
        assert_eq!(procs.len(), 2);
        assert_eq!(procs[0].get("name").unwrap().as_str(), Some("cpu0"));
        assert_eq!(procs[1].get("fast").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn arrays() {
        let t = parse("tiles = [128, 256, 512]\nnames = [\"a\", \"b\"]\nnested = [[1,2],[3]]\n").unwrap();
        let tiles = t.get("tiles").unwrap().as_arr().unwrap();
        assert_eq!(tiles.len(), 3);
        assert_eq!(tiles[2].as_i64(), Some(512));
        assert_eq!(t.get("names").unwrap().as_arr().unwrap()[1].as_str(), Some("b"));
        assert_eq!(t.get("nested").unwrap().as_arr().unwrap()[0].as_arr().unwrap().len(), 2);
    }

    #[test]
    fn underscored_numbers() {
        let t = parse("n = 32_768\n").unwrap();
        assert_eq!(t.get("n").unwrap().as_i64(), Some(32768));
    }

    #[test]
    fn mixed_sections() {
        let src = "top = 1\n[a]\nx = 2\n[[b]]\ny = 3\n[[b]]\ny = 4\n[a.c]\nz = 5\n";
        let t = parse(src).unwrap();
        assert_eq!(t.get("top").unwrap().as_i64(), Some(1));
        assert_eq!(t.get_path("a.x").unwrap().as_i64(), Some(2));
        assert_eq!(t.get_path("a.c.z").unwrap().as_i64(), Some(5));
        assert_eq!(t.get("b").unwrap().as_table_arr().unwrap()[1].get("y").unwrap().as_i64(), Some(4));
    }

    #[test]
    fn errors_are_reported() {
        assert!(parse("a =").is_err());
        assert!(parse("[unclosed\n").is_err());
        assert!(parse("a = 1\na = 2\n").is_err());
        assert!(parse("a = zzz\n").is_err());
    }

    #[test]
    fn tables_inside_table_array_entries() {
        let src = "[[proc]]\nname = \"p0\"\n[proc.perf]\npeak = 9.0\n[[proc]]\nname = \"p1\"\n[proc.perf]\npeak = 3.0\n";
        let t = parse(src).unwrap();
        let procs = t.get("proc").unwrap().as_table_arr().unwrap();
        assert_eq!(procs[0].get("perf").unwrap().get("peak").unwrap().as_f64(), Some(9.0));
        assert_eq!(procs[1].get("perf").unwrap().get("peak").unwrap().as_f64(), Some(3.0));
    }
}
