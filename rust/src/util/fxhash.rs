//! FxHash-style fast hasher (rustc's own non-cryptographic hash) for the
//! hot-path index maps: dependence-derivation and coherence queries hash
//! small `(u32, u32, u32)` keys millions of times per simulation, where
//! std's SipHash is the bottleneck (§Perf optimization 2).

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// Multiply-rotate hasher over machine words (the rustc-hash algorithm).
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut b = bytes;
        while b.len() >= 8 {
            self.add(u64::from_le_bytes(b[..8].try_into().unwrap()));
            b = &b[8..];
        }
        if b.len() >= 4 {
            self.add(u32::from_le_bytes(b[..4].try_into().unwrap()) as u64);
            b = &b[4..];
        }
        for &x in b {
            self.add(x as u64);
        }
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<K> = HashSet<K, FxBuildHasher>;

/// Content-derived deterministic seed — THE seed recipe of the sweep
/// harness (`cell_seed`, `workload_seed`) and the portfolio solver
/// (`lane_seed`): every label is hashed with a `0xff` separator (so
/// `("a","bc")` differs from `("ab","c")`), then the numeric coordinates,
/// and the raw hash is passed once through SplitMix64 so near-identical
/// inputs do not yield correlated RNG streams. Keep the three call sites
/// on this one helper: the recipe is determinism-critical, and divergent
/// copies would silently de-synchronize.
pub fn content_seed(labels: &[&str], nums: &[u64]) -> u64 {
    let mut h = FxHasher::default();
    for l in labels {
        h.write(l.as_bytes());
        h.write_u8(0xff); // field separator
    }
    for &n in nums {
        h.write_u64(n);
    }
    // detlint: allow(det/unseeded-rng) — this IS the seed recipe: the content hash is the seed, finalized by one SplitMix64 step
    crate::util::rng::Rng::new(h.finish()).next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works() {
        let mut m: FxHashMap<(u32, u32, u32), usize> = FxHashMap::default();
        for i in 0..1000u32 {
            m.insert((i, i * 2, i * 3), i as usize);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000u32 {
            assert_eq!(m.get(&(i, i * 2, i * 3)), Some(&(i as usize)));
        }
        assert_eq!(m.get(&(1, 1, 1)), None);
    }

    #[test]
    fn hash_distributes() {
        // crude avalanche check: nearby keys land in different buckets
        let mut buckets = [0usize; 16];
        for i in 0..1600u64 {
            let mut h = FxHasher::default();
            h.write_u64(i);
            buckets[(h.finish() % 16) as usize] += 1;
        }
        for &b in &buckets {
            assert!(b > 40, "bucket underfilled: {buckets:?}");
        }
    }

    #[test]
    fn deterministic() {
        let mut a = FxHasher::default();
        let mut b = FxHasher::default();
        a.write(b"hello world, this is a test");
        b.write(b"hello world, this is a test");
        assert_eq!(a.finish(), b.finish());
    }
}
