//! Shared descriptive statistics: linear-interpolation percentiles,
//! Jain's fairness index, mean and sample standard deviation. One
//! implementation serves both the bench harness ([`crate::bench::Stats`])
//! and the service-layer sojourn metrics
//! ([`crate::coordinator::service::metrics`]) — divergent copies of
//! percentile arithmetic would silently report different p99s.

/// Arithmetic mean. Panics on an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "mean of an empty sample");
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (Bessel-corrected, `/ (n-1)`): sample counts
/// are small in both call sites, and the population formula (`/ n`)
/// systematically understates their noise. A single sample reports 0.
pub fn sample_stddev(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "stddev of an empty sample");
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let ss = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>();
    (ss / (n - 1) as f64).sqrt()
}

/// Quantile `q` in `[0, 1]` of an ascending-sorted sample, with linear
/// interpolation at fractional rank `q * (n - 1)` (the NumPy default).
/// `q = 0.5` reproduces the textbook median, including the midpoint
/// average for even `n`. An empty sample has no quantiles and reports
/// `NaN` — a serve scenario where every job is rejected must summarize,
/// not panic. Panics on `q` outside `[0, 1]`; the sortedness
/// precondition is debug-asserted.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q), "quantile must be in [0, 1], got {q}");
    if sorted.is_empty() {
        return f64::NAN;
    }
    debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "percentile input must be sorted ascending");
    let rank = q * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (rank - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Jain's fairness index `(Σx)² / (n · Σx²)` over non-negative shares:
/// 1.0 when every share is equal, `1/n` when one share takes everything.
/// Degenerate inputs (empty, or all zero) report perfect fairness — no
/// one is being starved relative to anyone else.
pub fn jain(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    debug_assert!(xs.iter().all(|&x| x >= 0.0), "Jain's index is defined over non-negative shares");
    let s: f64 = xs.iter().sum();
    let s2: f64 = xs.iter().map(|x| x * x).sum();
    if s2 <= 0.0 {
        return 1.0;
    }
    s * s / (xs.len() as f64 * s2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev_closed_form() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(mean(&xs), 3.0);
        // sum of squares around the mean = 10 over 5 samples -> sqrt(10/4)
        assert!((sample_stddev(&xs) - 2.5f64.sqrt()).abs() < 1e-12);
        // two samples: sd = |a - b| / sqrt(2)
        assert!((sample_stddev(&[1.0, 2.0]) - 0.5f64.sqrt()).abs() < 1e-12);
        assert_eq!(sample_stddev(&[3.0]), 0.0, "a single sample carries no spread");
    }

    #[test]
    fn percentile_closed_form() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
        // rank 0.25 * 4 = 1.0 -> exactly the second sample
        assert_eq!(percentile(&xs, 0.25), 2.0);
        // rank 0.9 * 4 = 3.6 -> 4 + 0.6 * (5 - 4)
        assert!((percentile(&xs, 0.9) - 4.6).abs() < 1e-12);
    }

    #[test]
    fn percentile_median_matches_even_n_midpoint() {
        // the bench harness' historical even-n median: 0.5 * (x[n/2-1] + x[n/2])
        assert_eq!(percentile(&[1.0, 2.0], 0.5), 1.5);
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 10.0], 0.5), 2.5);
        assert_eq!(percentile(&[7.0], 0.5), 7.0);
    }

    #[test]
    #[should_panic]
    fn percentile_rejects_out_of_range_quantile() {
        percentile(&[1.0], 1.5);
    }

    #[test]
    fn percentile_of_empty_sample_is_nan() {
        // zero completions must flow through reporting as NaN, not panic
        assert!(percentile(&[], 0.5).is_nan());
        assert!(percentile(&[], 0.99).is_nan());
    }

    #[test]
    fn jain_closed_form() {
        assert_eq!(jain(&[5.0, 5.0, 5.0, 5.0]), 1.0, "equal shares are perfectly fair");
        // one share takes everything: 1/n
        assert!((jain(&[1.0, 0.0, 0.0, 0.0]) - 0.25).abs() < 1e-12);
        // (1+2+3)^2 / (3 * (1+4+9)) = 36/42
        assert!((jain(&[1.0, 2.0, 3.0]) - 36.0 / 42.0).abs() < 1e-12);
        assert_eq!(jain(&[]), 1.0);
        assert_eq!(jain(&[0.0, 0.0]), 1.0, "no one starves when no one consumes");
    }
}
