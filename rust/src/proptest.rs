//! Seeded property-testing helper (the proptest crate is unavailable
//! offline). Generates many random cases from a deterministic PRNG and
//! reports the failing seed so cases can be replayed exactly.
//!
//! ```no_run
//! use hesp::proptest::forall;
//! forall(500, 42, |rng| {
//!     let x = rng.below(100);
//!     assert!(x < 100, "x={x}");
//! });
//! ```

use crate::util::rng::Rng;

/// Run `prop` against `cases` random cases derived from `seed`. On panic,
/// re-raises with the per-case seed so the failure is reproducible via
/// [`replay`].
pub fn forall<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(cases: usize, seed: u64, prop: F) {
    for i in 0..cases {
        let case_seed = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(i as u64);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(case_seed);
            prop(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed on case {i} (replay seed {case_seed:#x}): {msg}");
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn replay<F: Fn(&mut Rng)>(case_seed: u64, prop: F) {
    let mut rng = Rng::new(case_seed);
    prop(&mut rng);
}

/// Helpers for building random structured inputs.
pub mod gen {
    use crate::coordinator::region::Region;
    use crate::util::rng::Rng;

    /// Random non-degenerate region inside a `dim x dim` matrix, with
    /// coordinates aligned to `align` (0 or 1 = unaligned).
    pub fn region(rng: &mut Rng, matrix: u32, dim: u32, align: u32) -> Region {
        let a = align.max(1);
        let cells = dim / a;
        assert!(cells >= 1);
        let pick = |rng: &mut Rng| {
            let lo = rng.below(cells as usize) as u32;
            let hi = lo + 1 + rng.below((cells - lo) as usize) as u32;
            (lo * a, hi * a)
        };
        let (r0, r1) = pick(rng);
        let (c0, c1) = pick(rng);
        Region::new(matrix, r0, r1, c0, c1)
    }

    /// Random square region with power-of-two edge, tile-aligned — the
    /// shape partitioners produce.
    pub fn square_tile(rng: &mut Rng, matrix: u32, dim_log2: u32) -> Region {
        let edge_log2 = rng.below(dim_log2 as usize) as u32; // 1..dim/2
        let edge = 1u32 << edge_log2;
        let dim = 1u32 << dim_log2;
        let slots = dim / edge;
        let i = rng.below(slots as usize) as u32;
        let j = rng.below(slots as usize) as u32;
        Region::new(matrix, i * edge, (i + 1) * edge, j * edge, (j + 1) * edge)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(100, 7, |rng| {
            let x = rng.below(10);
            assert!(x < 10);
        });
    }

    #[test]
    fn forall_reports_failing_seed() {
        let r = std::panic::catch_unwind(|| {
            forall(50, 3, |rng| {
                assert!(rng.below(2) != 1, "hit the bad value");
            })
        });
        let err = r.expect_err("property should fail eventually");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("replay seed"), "{msg}");
    }

    #[test]
    fn gen_region_is_valid_and_aligned() {
        forall(500, 11, |rng| {
            let r = gen::region(rng, 0, 64, 8);
            assert!(r.r0 < r.r1 && r.c0 < r.c1);
            assert!(r.r1 <= 64 && r.c1 <= 64);
            assert_eq!(r.r0 % 8, 0);
            assert_eq!(r.r1 % 8, 0);
        });
    }

    #[test]
    fn gen_square_tile_is_power_of_two() {
        forall(200, 13, |rng| {
            let r = gen::square_tile(rng, 0, 6);
            assert!(r.is_square());
            assert!(r.rows().is_power_of_two());
            assert!(r.r1 <= 64);
        });
    }
}
