//! Platform/experiment configuration loading (`configs/*.toml`).
//!
//! A platform file describes memory spaces, links, processor types with
//! their performance curves, and processor instances — everything HeSP
//! needs as its "hardware platform description" input (§2). Example:
//!
//! ```toml
//! name = "bujaruelo"
//! main_space = "host"
//! elem_bytes = 4
//!
//! [[memory]]
//! name = "host"
//! capacity_gb = 256.0
//!
//! [[link]]
//! from = "host"
//! to = "gtx980a_mem"
//! latency_us = 10.0
//! bandwidth_gbs = 12.0
//!
//! [[proctype]]
//! name = "xeon"
//! busy_watts = 9.0
//! idle_watts = 2.0
//! overhead_us = 4.0
//!
//! [perf.xeon.gemm]        # Saturating curve
//! peak = 43.0
//! half = 90.0
//! exponent = 1.7
//!
//! [perf.xeon.default]     # fallback for unlisted kinds
//! peak = 25.0
//! half = 90.0
//! exponent = 1.7
//!
//! [[processor]]
//! prefix = "xeon"
//! count = 28
//! type = "xeon"
//! space = "host"
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::perfmodel::{PerfCurve, PerfDb};
use crate::coordinator::platform::{Link, Machine, MemSpace, ProcType, Processor};
use crate::coordinator::policy::{policy_by_name, SchedPolicy};
use crate::coordinator::task::TaskKind;
use crate::util::toml::{parse, Toml};

/// A loaded platform: machine topology + performance database.
pub struct Platform {
    pub machine: Machine,
    pub db: PerfDb,
    /// Bytes per element for this platform's experiments (4 = f32, 8 = f64).
    pub elem_bytes: u64,
    /// Default scheduling policy for this platform's experiments, from the
    /// optional top-level `policy = "pl/eft-p"` key — a registry name,
    /// validated at load time. CLI `--policy` overrides it.
    pub default_policy: Option<String>,
}

impl Platform {
    pub fn from_file<P: AsRef<Path>>(path: P) -> Result<Platform> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Platform::from_str(&text)
    }

    pub fn from_str(text: &str) -> Result<Platform> {
        let doc = parse(text).map_err(|e| anyhow!(e))?;
        build(&doc, true)
    }

    /// Parse a platform *without* the final machine-consistency check.
    /// This is the `hesp check` entry point: the sanitizer wants to
    /// collect every problem via [`Machine::diagnostics`] instead of
    /// failing on the first one.
    pub fn from_str_unchecked(text: &str) -> Result<Platform> {
        let doc = parse(text).map_err(|e| anyhow!(e))?;
        build(&doc, false)
    }

    /// Construct this platform's default policy (the registry build of the
    /// `policy` key), or `None` when the config names no policy.
    pub fn policy(&self) -> Option<Box<dyn SchedPolicy>> {
        self.default_policy.as_deref().and_then(policy_by_name)
    }
}

fn get_str<'a>(t: &'a BTreeMap<String, Toml>, k: &str) -> Result<&'a str> {
    t.get(k).and_then(|v| v.as_str()).ok_or_else(|| anyhow!("missing string key '{k}'"))
}

fn get_f64(t: &BTreeMap<String, Toml>, k: &str) -> Result<f64> {
    t.get(k).and_then(|v| v.as_f64()).ok_or_else(|| anyhow!("missing number key '{k}'"))
}

fn build(doc: &Toml, strict: bool) -> Result<Platform> {
    let name = doc.get("name").and_then(|v| v.as_str()).unwrap_or("unnamed").to_string();
    let elem_bytes = doc.get("elem_bytes").and_then(|v| v.as_i64()).unwrap_or(4) as u64;

    // optional default scheduling policy, validated against the registry
    // so a typo fails at load time rather than mid-experiment
    let default_policy = match doc.get("policy").and_then(|v| v.as_str()) {
        Some(p) => {
            let canonical = policy_by_name(p)
                .ok_or_else(|| anyhow!("unknown scheduling policy '{p}' (try `hesp policies` for the registry)"))?
                .name()
                .to_string();
            Some(canonical)
        }
        None => None,
    };

    // ---- memory spaces ----
    let mems = doc
        .get("memory")
        .and_then(|v| v.as_table_arr())
        .ok_or_else(|| anyhow!("no [[memory]] sections"))?;
    let mut spaces = Vec::new();
    let mut space_ids: BTreeMap<String, usize> = BTreeMap::new();
    for m in mems {
        let nm = get_str(m, "name")?.to_string();
        let capacity = match m.get("capacity_gb").and_then(|v| v.as_f64()) {
            Some(gb) => (gb * (1u64 << 30) as f64) as u64,
            None => u64::MAX,
        };
        let id = spaces.len();
        if space_ids.insert(nm.clone(), id).is_some() {
            bail!("duplicate memory space '{nm}'");
        }
        spaces.push(MemSpace { id, name: nm, capacity });
    }
    let main_name = doc
        .get("main_space")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("missing string key 'main_space'"))?;
    let main_space = *space_ids.get(main_name).ok_or_else(|| anyhow!("unknown main_space '{main_name}'"))?;

    // ---- links ----
    let mut links = Vec::new();
    if let Some(ls) = doc.get("link").and_then(|v| v.as_table_arr()) {
        for l in ls {
            let from = *space_ids.get(get_str(l, "from")?).ok_or_else(|| anyhow!("link from unknown space"))?;
            let to = *space_ids.get(get_str(l, "to")?).ok_or_else(|| anyhow!("link to unknown space"))?;
            let latency = get_f64(l, "latency_us")? * 1e-6;
            let bandwidth = get_f64(l, "bandwidth_gbs")? * 1e9;
            let bidir = l.get("bidirectional").and_then(|v| v.as_bool()).unwrap_or(true);
            let id = links.len();
            links.push(Link { id, from, to, latency, bandwidth });
            if bidir {
                let id = links.len();
                links.push(Link { id, from: to, to: from, latency, bandwidth });
            }
        }
    }

    // ---- processor types + perf models ----
    let pts = doc
        .get("proctype")
        .and_then(|v| v.as_table_arr())
        .ok_or_else(|| anyhow!("no [[proctype]] sections"))?;
    let mut proc_types = Vec::new();
    let mut type_ids: BTreeMap<String, usize> = BTreeMap::new();
    let mut db = PerfDb::new();
    for pt in pts {
        let nm = get_str(pt, "name")?.to_string();
        let id = proc_types.len();
        if type_ids.insert(nm.clone(), id).is_some() {
            bail!("duplicate proctype '{nm}'");
        }
        proc_types.push(ProcType {
            id,
            name: nm.clone(),
            busy_watts: pt.get("busy_watts").and_then(|v| v.as_f64()).unwrap_or(0.0),
            idle_watts: pt.get("idle_watts").and_then(|v| v.as_f64()).unwrap_or(0.0),
        });
        if let Some(oh) = pt.get("overhead_us").and_then(|v| v.as_f64()) {
            db.set_overhead(id, oh * 1e-6);
        }
        // perf.<type>.<task> sections
        if let Some(perf) = doc.get_path(&format!("perf.{nm}")) {
            let table = perf.as_table().ok_or_else(|| anyhow!("perf.{nm} is not a table"))?;
            for (task_name, curve_toml) in table {
                let curve = parse_curve(curve_toml).with_context(|| format!("perf.{nm}.{task_name}"))?;
                if task_name == "default" {
                    db.set_fallback(id, curve);
                } else {
                    let kind = TaskKind::from_name(task_name)
                        .ok_or_else(|| anyhow!("unknown task kind '{task_name}' in perf.{nm}"))?;
                    db.set(id, kind, curve);
                }
            }
        } else {
            bail!("no [perf.{nm}.*] sections for proctype '{nm}'");
        }
    }

    // ---- processors ----
    let ps = doc
        .get("processor")
        .and_then(|v| v.as_table_arr())
        .ok_or_else(|| anyhow!("no [[processor]] sections"))?;
    let mut procs = Vec::new();
    for p in ps {
        let prefix = get_str(p, "prefix")?;
        let count = p.get("count").and_then(|v| v.as_i64()).unwrap_or(1) as usize;
        let ptype = *type_ids.get(get_str(p, "type")?).ok_or_else(|| anyhow!("processor of unknown type"))?;
        let space = *space_ids.get(get_str(p, "space")?).ok_or_else(|| anyhow!("processor in unknown space"))?;
        for i in 0..count {
            let id = procs.len();
            procs.push(Processor { id, name: format!("{prefix}{i}"), ptype, space });
        }
    }

    let machine = Machine { name, spaces, links, proc_types, procs, main_space };
    if strict {
        machine.validate().map_err(|e| anyhow!(e))?;
    }
    Ok(Platform { machine, db, elem_bytes, default_policy })
}

fn parse_curve(t: &Toml) -> Result<PerfCurve> {
    let table = t.as_table().ok_or_else(|| anyhow!("curve is not a table"))?;
    if let Some(points) = table.get("points") {
        let arr = points.as_arr().ok_or_else(|| anyhow!("points must be an array"))?;
        let mut pts = Vec::new();
        for p in arr {
            let pair = p.as_arr().ok_or_else(|| anyhow!("point must be [edge, gflops]"))?;
            if pair.len() != 2 {
                bail!("point must be [edge, gflops]");
            }
            pts.push((pair[0].as_f64().unwrap_or(0.0), pair[1].as_f64().unwrap_or(0.0)));
        }
        pts.sort_by(|a, b| a.0.total_cmp(&b.0));
        if pts.is_empty() {
            bail!("empty points table");
        }
        return Ok(PerfCurve::Table { points: pts });
    }
    if let Some(g) = table.get("gflops").and_then(|v| v.as_f64()) {
        return Ok(PerfCurve::Const { gflops: g });
    }
    let peak = get_f64(table, "peak")?;
    let half = get_f64(table, "half")?;
    let exponent = table.get("exponent").and_then(|v| v.as_f64()).unwrap_or(2.0);
    Ok(PerfCurve::Saturating { peak, half, exponent })
}

#[cfg(test)]
mod tests {
    use super::*;

    const TOY: &str = r#"
name = "toy"
main_space = "host"
elem_bytes = 8

[[memory]]
name = "host"

[[memory]]
name = "gpu_mem"
capacity_gb = 4.0

[[link]]
from = "host"
to = "gpu_mem"
latency_us = 10.0
bandwidth_gbs = 12.0

[[proctype]]
name = "cpu"
busy_watts = 20.0
idle_watts = 5.0
overhead_us = 2.0

[perf.cpu.gemm]
peak = 40.0
half = 64.0
exponent = 2.0

[perf.cpu.default]
gflops = 10.0

[[proctype]]
name = "gpu"
busy_watts = 180.0
idle_watts = 30.0

[perf.gpu.default]
points = [[128, 100.0], [1024, 900.0]]

[[processor]]
prefix = "c"
count = 4
type = "cpu"
space = "host"

[[processor]]
prefix = "g"
count = 1
type = "gpu"
space = "gpu_mem"
"#;

    #[test]
    fn parses_toy_platform() {
        let p = Platform::from_str(TOY).unwrap();
        assert_eq!(p.machine.name, "toy");
        assert_eq!(p.machine.spaces.len(), 2);
        assert_eq!(p.machine.links.len(), 2, "bidirectional default");
        assert_eq!(p.machine.procs.len(), 5);
        assert_eq!(p.elem_bytes, 8);
        assert_eq!(p.machine.main_space, 0);
        assert_eq!(p.machine.spaces[1].capacity, 4 << 30);
    }

    #[test]
    fn perf_models_resolve() {
        let p = Platform::from_str(TOY).unwrap();
        let g = p.db.curve(0, TaskKind::Gemm).gflops(64.0);
        assert!((g - 20.0).abs() < 1e-9, "saturating half point");
        assert_eq!(p.db.curve(0, TaskKind::Trsm).gflops(64.0), 10.0, "fallback");
        assert_eq!(p.db.curve(1, TaskKind::Gemm).gflops(64.0), 100.0, "table clamp");
        // overhead applied for cpu
        let t = p.db.time(0, TaskKind::Trsm, 64.0, 10e9);
        assert!((t - (1.0 + 2e-6)).abs() < 1e-9);
    }

    #[test]
    fn rejects_missing_perf() {
        let bad = r#"
name = "x"
main_space = "host"
[[memory]]
name = "host"
[[proctype]]
name = "cpu"
[[processor]]
prefix = "c"
type = "cpu"
space = "host"
"#;
        assert!(Platform::from_str(bad).is_err());
    }

    #[test]
    fn rejects_unknown_spaces() {
        let bad = r#"
name = "x"
main_space = "nope"
[[memory]]
name = "host"
[[proctype]]
name = "cpu"
[perf.cpu.default]
gflops = 1.0
[[processor]]
prefix = "c"
type = "cpu"
space = "host"
"#;
        assert!(Platform::from_str(bad).is_err());
    }

    #[test]
    fn policy_key_resolves_and_canonicalizes() {
        let p = Platform::from_str(TOY).unwrap();
        assert_eq!(p.default_policy, None, "TOY names no policy");
        assert!(p.policy().is_none());
        // alias spelling canonicalizes through the registry
        let with = format!("policy = \"PL/EFT\"\n{TOY}");
        let p = Platform::from_str(&with).unwrap();
        assert_eq!(p.default_policy.as_deref(), Some("pl/eft-p"));
        assert_eq!(p.policy().unwrap().name(), "pl/eft-p");
    }

    #[test]
    fn unknown_policy_rejected_at_load() {
        let bad = format!("policy = \"pl/does-not-exist\"\n{TOY}");
        let err = Platform::from_str(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("unknown scheduling policy"), "{err:#}");
    }

    #[test]
    fn shipped_configs_load() {
        // every file in configs/ must parse and validate
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
        let mut n = 0;
        for entry in std::fs::read_dir(&dir).unwrap() {
            let path = entry.unwrap().path();
            if path.extension().map(|e| e == "toml").unwrap_or(false) {
                Platform::from_file(&path).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
                n += 1;
            }
        }
        assert!(n >= 3, "expected >= 3 shipped platform configs, found {n}");
    }
}
