//! Minimal measurement harness (criterion is unavailable offline).
//!
//! `rust/benches/*.rs` use [`Bench`] both to time hot paths (warmup +
//! repeated samples, median/mean/stddev) and to print the experiment
//! tables/figures the paper reports. Results can also be dumped as JSON
//! for EXPERIMENTS.md bookkeeping.

use std::time::Instant;

/// Timing statistics over n samples. `stddev_s` is the *sample* standard
/// deviation (Bessel-corrected, `/ (n-1)`): bench sample counts are small,
/// and the population formula (`/ n`) systematically understates the
/// noise of exactly those runs. A single sample reports 0.
///
/// All arithmetic lives in [`crate::util::stats`] — the same percentile
/// and spread formulas the service-layer metrics report, so a bench
/// median and a serve p50 can never disagree on definition.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub n: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub stddev_s: f64,
}

impl Stats {
    pub fn from_samples(mut xs: Vec<f64>) -> Stats {
        use crate::util::stats::{mean, percentile, sample_stddev};
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.total_cmp(b));
        let n = xs.len();
        Stats {
            n,
            mean_s: mean(&xs),
            median_s: percentile(&xs, 0.5),
            min_s: xs[0],
            max_s: xs[n - 1],
            stddev_s: sample_stddev(&xs),
        }
    }
}

/// A named bench run.
pub struct Bench {
    pub name: String,
    /// Minimum number of timed samples.
    pub samples: usize,
    /// Warmup iterations before timing.
    pub warmup: usize,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        Bench { name: name.to_string(), samples: 10, warmup: 2 }
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n.max(1);
        self
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    /// Time `f` (its return value is black-boxed) and print one line.
    pub fn run<T, F: FnMut() -> T>(&self, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut xs = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            black_box(f());
            xs.push(t0.elapsed().as_secs_f64());
        }
        let st = Stats::from_samples(xs);
        println!(
            "bench {:<40} median {:>12}  mean {:>12}  (n={}, sd {:.1}%)",
            self.name,
            fmt_time(st.median_s),
            fmt_time(st.mean_s),
            st.n,
            if st.mean_s > 0.0 { 100.0 * st.stddev_s / st.mean_s } else { 0.0 },
        );
        st
    }
}

/// Opaque value sink (prevents the optimizer from deleting the work).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Human-readable seconds.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Simple fixed-width table printer for experiment output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:>w$} |", w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.median_s, 3.0);
        assert_eq!(s.mean_s, 3.0);
        assert_eq!((s.min_s, s.max_s), (1.0, 5.0));
        let s2 = Stats::from_samples(vec![1.0, 2.0]);
        assert_eq!(s2.median_s, 1.5);
    }

    #[test]
    fn stddev_is_sample_not_population() {
        // sum of squares around the mean = 10 over 5 samples:
        // population sd would be sqrt(10/5), sample sd is sqrt(10/4)
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!((s.stddev_s - 2.5f64.sqrt()).abs() < 1e-12, "{}", s.stddev_s);
        // two samples: sd = |a - b| / sqrt(2)
        let s2 = Stats::from_samples(vec![1.0, 2.0]);
        assert!((s2.stddev_s - 0.5f64.sqrt()).abs() < 1e-12, "{}", s2.stddev_s);
        // a single sample carries no spread information
        assert_eq!(Stats::from_samples(vec![3.0]).stddev_s, 0.0);
    }

    #[test]
    fn bench_runs_and_counts() {
        let mut calls = 0;
        let st = Bench::new("noop").samples(3).warmup(1).run(|| calls += 1);
        assert_eq!(st.n, 3);
        assert_eq!(calls, 4);
    }

    #[test]
    fn fmt_time_scales() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" us"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "bbb"]);
        t.row(&["1".into(), "2".into()]);
        let s = t.to_string();
        assert!(s.contains("| a | bbb |"));
        assert_eq!(s.lines().count(), 3);
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
