//! # HeSP — Heterogeneous Scheduler-Partitioner
//!
//! A production-grade reproduction of *"HeSP: a simulation framework for
//! solving the task scheduling-partitioning problem on heterogeneous
//! architectures"* (Rey, Igual, Prieto-Matías, 2016).
//!
//! HeSP treats recursive task **partitioning** and task **scheduling** as a
//! joint optimization problem: tasks can be dynamically split into finer
//! sub-tasks (or merged back) per processor type, exposing exactly as much
//! parallelism as the platform can absorb at each execution phase.
//!
//! The crate is organized as the three-layer architecture described in
//! `DESIGN.md` (repository root):
//!
//! * [`coordinator`] — the simulation framework itself (task DAG, data DAG
//!   + coherence, the pluggable scheduling-policy layer, iterative
//!   scheduler-partitioner, metrics, traces, energy).
//! * [`analysis`] — the detlint static-analysis pass (`hesp lint`) and the
//!   input sanitizer (`hesp check`) guarding the bit-reproducibility
//!   invariant at CI time.
//! * [`runtime`] — the XLA/PJRT runtime that loads AOT-compiled JAX/Pallas
//!   tile kernels (`artifacts/*.hlo.txt`) and executes scheduled DAGs for
//!   real, providing the validation substrate of §3.1.
//! * [`config`] — TOML platform/experiment descriptions (`configs/`),
//!   including the optional `policy = "..."` default-policy key.
//! * [`util`] — offline-friendly substrates (PRNG, JSON, TOML, CLI).
//! * [`bench`] — a small measurement harness used by `rust/benches/`.
//! * [`proptest`] — a seeded property-testing helper used by the test
//!   suite.
//!
//! Scheduling is an open API: implement
//! [`coordinator::policy::SchedPolicy`] and register it in a
//! [`coordinator::policy::PolicyRegistry`] to drive the engine, the
//! iterative solver, and the constructive online scheduler with your own
//! heuristic (see `examples/custom_policy.rs`). The classic Table-1
//! configurations are registry entries `"fcfs/r-p"` ... `"pl/eft-p"`;
//! `"pl/affinity"` and `"pl/lookahead"` extend them with data-placement
//! awareness and one-step successor lookahead.

#![forbid(unsafe_code)]

pub mod analysis;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod proptest;
pub mod runtime;
pub mod util;
