//! # HeSP — Heterogeneous Scheduler-Partitioner
//!
//! A production-grade reproduction of *"HeSP: a simulation framework for
//! solving the task scheduling-partitioning problem on heterogeneous
//! architectures"* (Rey, Igual, Prieto-Matías, 2016).
//!
//! HeSP treats recursive task **partitioning** and task **scheduling** as a
//! joint optimization problem: tasks can be dynamically split into finer
//! sub-tasks (or merged back) per processor type, exposing exactly as much
//! parallelism as the platform can absorb at each execution phase.
//!
//! The crate is organized as the three-layer architecture described in
//! `DESIGN.md`:
//!
//! * [`coordinator`] — the simulation framework itself (task DAG, data DAG
//!   + coherence, scheduling heuristics, iterative scheduler-partitioner,
//!   metrics, traces, energy).
//! * [`runtime`] — the XLA/PJRT runtime that loads AOT-compiled JAX/Pallas
//!   tile kernels (`artifacts/*.hlo.txt`) and executes scheduled DAGs for
//!   real, providing the validation substrate of §3.1.
//! * [`config`] — TOML platform/experiment descriptions (`configs/`).
//! * [`util`] — offline-friendly substrates (PRNG, JSON, TOML, CLI).
//! * [`bench`] — a small measurement harness used by `rust/benches/`.
//! * [`proptest`] — a seeded property-testing helper used by the test
//!   suite.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod proptest;
pub mod runtime;
pub mod util;
