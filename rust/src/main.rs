//! `hesp` — command-line front-end of the HeSP framework.
//!
//! Subcommands:
//!
//! * `simulate`  — schedule one uniform tiling on a platform, print the report
//! * `sweep`     — policy x tile-size sweep (Fig. 5 right)
//! * `serve`     — streaming multi-DAG service mode: jobs arrive over time
//! * `solve`     — run the iterative scheduler-partitioner (Table 1 rows)
//! * `online`    — constructive per-task-arrival partitioner (paper §4)
//! * `table1`    — the full 8-configuration Table 1 for a platform
//! * `validate`  — real PJRT execution vs simulation (Fig. 5 left analog)
//! * `calibrate` — measure local kernel perf models, print TOML
//! * `trace`     — write Paraver/CSV trace bundles (Figs. 2b & 6)
//! * `dag`       — export the task DAG as Graphviz DOT (Fig. 2a)
//! * `policies`  — list the scheduling-policy registry
//! * `lint`      — detlint determinism/safety static analysis over the tree
//! * `check`     — statically validate platform/grid/trace input files
//!
//! Examples:
//!
//! ```text
//! hesp simulate --platform configs/bujaruelo.toml --n 32768 --tile 1024 \
//!               --policy pl/eft-p
//! hesp solve --platform configs/odroid.toml --n 8192 --iters 200
//! hesp simulate --platform configs/bujaruelo.toml --policy pl/affinity
//! hesp validate --n 512 --tiles 64,128 --reps 3
//! ```

use anyhow::{anyhow, bail, Result};

use hesp::bench::Table;
use hesp::config::Platform;
use hesp::coordinator::coherence::CachePolicy;
use hesp::coordinator::delta::DeltaMode;
use hesp::coordinator::energy::Objective;
use hesp::coordinator::engine::{simulate_policy, SimConfig};
use hesp::coordinator::faults::{FaultEnsemble, FaultSpec};
use hesp::coordinator::metrics::report;
use hesp::coordinator::partitioners::{cholesky, PartitionerSet};
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::coordinator::policy::{policy_for, PolicyRegistry, SchedPolicy};
use hesp::coordinator::solver::{
    best_homogeneous_with, result_json, solve_portfolio, solve_with, CandidateSelect, PortfolioConfig, Sampling,
    SolverConfig,
};
use hesp::coordinator::service::{self, Admission, ArrivalSpec, ServeGrid};
use hesp::coordinator::sweep::{self, CellMode, SweepGrid, SweepPlatform, Workload};
use hesp::coordinator::trace::write_bundle;
use hesp::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let r = match cmd {
        "simulate" => cmd_simulate(&args),
        "sweep" => cmd_sweep(&args),
        "serve" => cmd_serve(&args),
        "solve" => cmd_solve(&args),
        "online" => cmd_online(&args),
        "table1" => cmd_table1(&args),
        "validate" => cmd_validate(&args),
        "calibrate" => cmd_calibrate(&args),
        "trace" => cmd_trace(&args),
        "dag" => cmd_dag(&args),
        "policies" => cmd_policies(),
        "lint" => cmd_lint(&args),
        "check" => cmd_check(&args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(anyhow!(
            "unknown subcommand '{other}' — expected one of: simulate, sweep, serve, solve, \
             online, table1, validate, calibrate, trace, dag, policies, lint, check, help"
        )),
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
hesp — Heterogeneous Scheduler-Partitioner (Rey, Igual, Prieto-Matias 2016)

USAGE: hesp <subcommand> [--flags]

  simulate  --platform F --n N --tile B [--policy NAME] [--cache wb|wt|wa] [--seed S]
  sweep     --platform F | --platforms F1,F2 | --grid FILE.toml | --quick
            [--workloads cholesky:N,lu:N,qr:N,layered:LxW,stencil:CxS,random:N]
            [--policies all|name,...] [--tiles 256,512,...] [--threads T]
            [--modes sim,solve:ITERS:MINEDGE | --solve --iters K --min-edge E]
            [--solve-lanes M] [--solve-batch K] [--delta on|off|auto]
            [--faults off,SPEC.toml,...] [--fault-members N]
            [--seeds 0,1,...] [--cache wb|wt|wa] [--out bench_out/sweep.csv]
            (parallel scenario grid; cells get content-derived seeds, so any
            --threads count emits a byte-identical aggregate CSV/JSON bundle.
            bare --quick = the self-contained 480-cell CI smoke grid)
  serve     --platform F | --platforms F1,F2 | --quick
            [--arrivals poisson:R,bursty:LO:HI:DWELL,trace:FILE.jsonl]
            [--rate R] [--duration S] [--policies all|name,...] [--cap N]
            [--admission defer|reject] [--max-defer SECS] [--threads T]
            [--faults SPEC.toml] [--cache wb|wt|wa]
            [--seed S] [--out bench_out/serve.csv] [--bench-json FILE.json]
            (streaming multi-DAG service mode: jobs arrive over time, pass
            admission control, and are co-scheduled on the shared machine
            until drain. Streams and scheduler seeds are content-derived,
            so any --threads count emits a byte-identical CSV/JSON bundle
            of sojourn percentiles, throughput, deadline-miss rate and
            Jain fairness. bare --quick = the 16-scenario CI smoke grid)
  solve     --platform F | --quick   --n N [--tiles ...] [--iters K]
            [--candidates all|cp|shallow] [--sampling hard|soft] [--min-edge E]
            [--objective makespan|energy|edp] [--policy NAME]
            [--threads T] [--portfolio M] [--batch K] [--delta on|off|auto]
            [--faults SPEC.toml] [--fault-members N]
            [--out FILE.json] [--bench-json FILE.json]
            (Table 1 rows; the parallel portfolio solver runs M restart
            lanes x K-candidate batches over T workers — byte-identical
            output for any T. --delta enables incremental re-simulation:
            candidates replay from the nearest checkpoint of the incumbent
            run when provably equivalent, full simulation otherwise — the
            canonical JSON is identical in every mode; replay counters go
            to stdout and --bench-json only. --out writes the canonical
            solver JSON the CI determinism smoke cmps; bare --quick =
            self-contained bujaruelo smoke cell)
  online    --platform F --n N --tile B [--min-edge E] [--policy NAME]
            (constructive per-task-arrival partitioner, paper §4)
  table1    --platform F --n N [--tiles ...] [--iters K]  (full Table 1 + new policies)
  validate  [--n N] [--tiles 64,128] [--reps R]           (Fig. 5 left; needs artifacts)
  calibrate [--tiles 32,64,128] [--reps R]                (refresh configs/local.toml)
  trace     --platform F --n N --tile B [--out DIR] [--solve-iters K]  (Figs. 2b & 6)
  dag       --n N --tile B [--out FILE.dot]               (Fig. 2a)
  policies                                                (list the policy registry)
  lint      [--root DIR] [--json FILE]
            (detlint static analysis: determinism & schedule-safety rules
            over src/ and examples/. Byte-stable report; nonzero exit on
            any unsuppressed finding. Suppress a line with a reasoned
            pragma: `// detlint: allow(<rule>) — <reason>`)
  check     [FILES...] [--root DIR]
            (static input sanitizer: validates platform TOMLs, sweep-grid
            TOMLs, fault-spec TOMLs and JSONL traces before any simulation
            — disconnected spaces, zero-rate curves, infeasible
            workload/tile combos, non-monotonic traces, duplicate job ids,
            malformed fault windows. With no FILES, checks every shipped
            configs/*.toml and examples/ input)

Scheduling policies are named registry entries (`hesp policies`):
fcfs/r-p ... pl/eft-p (Table 1), pl/affinity, pl/lookahead, and the
job-aware serve pair pl/edf-p / pl/sjf-p. For the single-policy commands
(simulate/solve/online/trace) the precedence is --policy > legacy
--order/--select pair > the platform's `policy =` key > pl/eft-p. sweep
and table1 run every registered policy by default; sweep restricts to one
when --policy (or --order/--select) is given. serve defaults to the
service four (fcfs/eft-p, pl/eft-p, pl/edf-p, pl/sjf-p).

Fault injection (--faults): a fault-spec TOML (kind = \"faults\") declares
seeded fail-stop processor outages, transient per-attempt task faults,
throttle windows and link outages. sweep takes a comma list as an extra
grid axis (entries are \"off\" or a spec path); serve injects one spec into
every scenario and switches the bundle to the extended failure/goodput
columns; solve prices every candidate against a --fault-members ensemble
and optimizes expected cost (the reported schedule is the nominal run).
Fault traces are content-seeded: any --threads count replays the same
faults byte-for-byte, and `--faults off` output is identical to omitting
the flag. See configs/faults_quick.toml and DESIGN.md for the schema.
";

fn sim_config(args: &Args, p: &Platform) -> Result<SimConfig> {
    // with --policy the legacy shim flags are dead — don't fail on them
    let lenient = args.has("policy");
    let ordering = match Ordering::from_name(&args.str_lower_or("order", "pl")) {
        Some(o) => o,
        None if lenient => Ordering::PriorityList,
        None => return Err(anyhow!("bad --order")),
    };
    let select = match ProcSelect::from_name(&args.str_lower_or("select", "eft")) {
        Some(s) => s,
        None if lenient => ProcSelect::EarliestFinish,
        None => return Err(anyhow!("bad --select")),
    };
    let cache = CachePolicy::from_name(&args.str_lower_or("cache", "wb")).ok_or_else(|| anyhow!("bad --cache"))?;
    Ok(SimConfig::new(SchedConfig::new(ordering, select))
        .with_cache(cache)
        .with_elem_bytes(p.elem_bytes)
        .with_seed(args.u64_or("seed", 0)))
}

/// Resolve the scheduling policy for a command: `--policy NAME` (registry
/// lookup) beats the legacy `--order`/`--select` pair, which beats the
/// platform config's `policy =` key, which beats the PL/EFT-P default.
fn build_policy(args: &Args, p: &Platform) -> Result<Box<dyn SchedPolicy>> {
    if let Some(name) = args.get_lower("policy") {
        // resolve() reports ambiguous bare suffixes with the candidate list
        return PolicyRegistry::standard().resolve(&name).map_err(|e| anyhow!(e));
    }
    if !args.has("order") && !args.has("select") {
        if let Some(pol) = p.policy() {
            return Ok(pol);
        }
    }
    let ordering = Ordering::from_name(&args.str_lower_or("order", "pl")).ok_or_else(|| anyhow!("bad --order"))?;
    let select = ProcSelect::from_name(&args.str_lower_or("select", "eft")).ok_or_else(|| anyhow!("bad --select"))?;
    Ok(policy_for(SchedConfig::new(ordering, select)))
}

fn cmd_policies() -> Result<()> {
    let reg = PolicyRegistry::standard();
    println!("registered scheduling policies ({} — Table 1 rows + extensions):", reg.len());
    for name in reg.names() {
        println!("  {name}");
    }
    println!("\naliases: enum spellings (pl/eft, fcfs/random, ...) and bare pl/ suffixes (affinity, eft-p, ...)");
    Ok(())
}

fn load_platform(args: &Args) -> Result<Platform> {
    let path = args.get("platform").ok_or_else(|| anyhow!("--platform <file.toml> required"))?;
    Platform::from_file(path)
}

fn print_report(label: &str, dag: &hesp::coordinator::taskdag::TaskDag, sched: &hesp::coordinator::engine::Schedule) {
    let r = report(dag, sched);
    println!(
        "{label}: makespan {:.4}s  {:.2} GFLOPS  load {:.1}%  avg-block {:.1}  depth {}  tasks {}  xfer {:.1} MB",
        r.makespan,
        r.gflops,
        r.avg_load_pct,
        r.avg_block_size,
        r.dag_depth,
        r.n_tasks,
        r.transfer_bytes as f64 / 1e6
    );
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let p = load_platform(args)?;
    let n = args.usize_or("n", 16384) as u32;
    let b = args.usize_or("tile", 1024) as u32;
    let cfg = sim_config(args, &p)?;
    let mut pol = build_policy(args, &p)?;
    let mut dag = cholesky::root(n);
    cholesky::partition_uniform(&mut dag, b);
    let sched = simulate_policy(&dag, &p.machine, &p.db, cfg, pol.as_mut());
    print_report(&format!("{} n={n} b={b} [{}]", p.machine.name, pol.name()), &dag, &sched);
    Ok(())
}

fn default_tiles(n: u32) -> Vec<usize> {
    [256usize, 512, 1024, 2048, 4096]
        .into_iter()
        .filter(|&b| (b as u32) < n && n % b as u32 == 0)
        .collect()
}

/// Parse `--delta on|off|auto` (default `auto`: incremental re-simulation
/// wherever the lane policy is provably replay-safe, full evaluation
/// elsewhere — the result bytes are identical in every mode).
fn delta_flag(args: &Args) -> Result<DeltaMode> {
    let s = args.str_lower_or("delta", "auto");
    DeltaMode::from_name(&s).ok_or_else(|| anyhow!("bad --delta '{s}' (on | off | auto)"))
}

/// Parse `--faults off,SPEC.toml,...` into the sweep fault axis. Each
/// entry is either the literal `off` (a fault-free scenario) or a path
/// to a fault-spec TOML; no flag means a single fault-free axis entry.
fn faults_axis_flag(args: &Args) -> Result<Vec<Option<FaultSpec>>> {
    let Some(list) = args.get("faults") else {
        return Ok(vec![None]);
    };
    let mut out = Vec::new();
    for e in list.split(',') {
        let e = e.trim();
        if e.is_empty() {
            continue;
        }
        if e.eq_ignore_ascii_case("off") {
            out.push(None);
        } else {
            out.push(Some(FaultSpec::from_file(e).map_err(|msg| anyhow!(msg))?));
        }
    }
    if out.is_empty() {
        out.push(None);
    }
    Ok(out)
}

/// Parse `--faults SPEC.toml` as a single optional spec (serve / solve,
/// where faults are a scenario property rather than a sweep axis).
fn faults_spec_flag(args: &Args) -> Result<Option<FaultSpec>> {
    match args.get("faults") {
        None => Ok(None),
        Some(path) if path.eq_ignore_ascii_case("off") => Ok(None),
        Some(path) => Ok(Some(FaultSpec::from_file(path).map_err(|msg| anyhow!(msg))?)),
    }
}

/// Parse `--fault-members N`: how many seeded fault-trace realisations an
/// ensemble averages over when pricing candidates under `--faults`.
fn fault_members_flag(args: &Args) -> u64 {
    args.usize_or("fault-members", 3).max(1) as u64
}

/// Build the declarative scenario grid for `hesp sweep`: an explicit
/// `--grid FILE.toml` wins; `--quick` (without a platform) is the
/// self-contained CI smoke grid; otherwise the grid comes from flags.
fn build_sweep_grid(args: &Args) -> Result<SweepGrid> {
    use anyhow::Context;
    if let Some(path) = args.get("grid") {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading grid file {path}"))?;
        let mut grid = sweep::grid_from_toml(&text)?;
        // the CLI knobs override the grid file only when explicitly given
        if args.has("delta") {
            grid.delta = delta_flag(args)?;
        }
        if args.has("faults") {
            grid.faults = faults_axis_flag(args)?;
        }
        if args.has("fault-members") {
            grid.fault_members = fault_members_flag(args);
        }
        return Ok(grid);
    }

    let reg = PolicyRegistry::standard();
    let all_policies = || reg.names().iter().map(|s| s.to_string()).collect::<Vec<_>>();
    let cache = CachePolicy::from_name(&args.str_lower_or("cache", "wb")).ok_or_else(|| anyhow!("bad --cache"))?;

    if args.has("quick") && !args.has("platform") && !args.has("platforms") {
        // the CI smoke grid: 2 platforms x 4 workloads x 15 policies x
        // 2 tiles x 2 seeds = 480 cells, sized to finish in seconds
        return Ok(SweepGrid {
            platforms: vec![
                SweepPlatform::from_file("configs/bujaruelo.toml")?,
                SweepPlatform::from_file("configs/odroid.toml")?,
            ],
            workloads: vec![
                Workload::Cholesky { n: 4096 },
                Workload::Lu { n: 4096 },
                Workload::Layered { layers: 6, width: 12 },
                Workload::Stencil { cells: 24, steps: 8 },
            ],
            policies: all_policies(),
            tiles: vec![256, 512],
            modes: vec![CellMode::Simulate],
            seeds: vec![0, 1],
            cache,
            solve_lanes: 1,
            solve_batch: 1,
            delta: delta_flag(args)?,
            faults: faults_axis_flag(args)?,
            fault_members: fault_members_flag(args),
        });
    }

    let mut platforms = Vec::new();
    if let Some(list) = args.get("platforms") {
        for p in list.split(',') {
            platforms.push(SweepPlatform::from_file(p.trim())?);
        }
    } else if let Some(p) = args.get("platform") {
        platforms.push(SweepPlatform::from_file(p)?);
    } else {
        bail!("--platform F | --platforms F1,F2 | --grid FILE.toml required (or bare --quick)");
    }

    let n = args.usize_or("n", 32768) as u32;
    let workloads = match args.get("workloads") {
        Some(list) => {
            let mut out = Vec::new();
            for w in list.split(',') {
                let w = w.trim();
                out.push(Workload::parse(w).ok_or_else(|| anyhow!("bad workload spec '{w}'"))?);
            }
            out
        }
        None => vec![Workload::Cholesky { n }],
    };

    let policies: Vec<String> = if let Some(list) = args.get_lower("policies") {
        if list == "all" {
            all_policies()
        } else {
            let mut out = Vec::new();
            for name in list.split(',') {
                let name = name.trim();
                let pol = reg.resolve(name).map_err(|e| anyhow!(e))?;
                out.push(pol.name().to_string());
            }
            out
        }
    } else if args.has("policy") {
        let name = args.get_lower("policy").unwrap();
        let pol = reg.resolve(&name).map_err(|e| anyhow!(e))?;
        vec![pol.name().to_string()]
    } else if args.has("order") || args.has("select") {
        // legacy shim pair restricts to the matching built-in
        let ordering = Ordering::from_name(&args.str_lower_or("order", "pl")).ok_or_else(|| anyhow!("bad --order"))?;
        let select =
            ProcSelect::from_name(&args.str_lower_or("select", "eft")).ok_or_else(|| anyhow!("bad --select"))?;
        vec![policy_for(SchedConfig::new(ordering, select)).name().to_string()]
    } else {
        all_policies()
    };

    let tiles: Vec<u32> = args.usize_list("tiles", &default_tiles(n)).into_iter().map(|x| x as u32).collect();

    let modes = match args.get_lower("modes") {
        Some(list) => {
            let mut out = Vec::new();
            for m in list.split(',') {
                let m = m.trim();
                out.push(CellMode::parse(m).ok_or_else(|| anyhow!("bad mode spec '{m}' (sim | solve:<iters>:<min_edge>)"))?);
            }
            out
        }
        None if args.has("solve") => vec![CellMode::Solve {
            iters: args.usize_or("iters", 150),
            min_edge: args.usize_or("min-edge", 64) as u32,
        }],
        None => vec![CellMode::Simulate],
    };

    let seeds: Vec<u64> = match args.get("seeds") {
        Some(s) => {
            let mut out = Vec::new();
            for x in s.split(',') {
                let x = x.trim();
                out.push(x.parse().map_err(|_| anyhow!("bad --seeds entry '{x}'"))?);
            }
            out
        }
        None => vec![args.u64_or("seed", 0)],
    };

    let solve_lanes = args.usize_or("solve-lanes", 1).max(1);
    let solve_batch = args.usize_or("solve-batch", 1).max(1);
    let delta = delta_flag(args)?;

    Ok(SweepGrid {
        platforms,
        workloads,
        policies,
        tiles,
        modes,
        seeds,
        cache,
        solve_lanes,
        solve_batch,
        delta,
        faults: faults_axis_flag(args)?,
        fault_members: fault_members_flag(args),
    })
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let threads = args.usize_or("threads", sweep::default_threads());
    let grid = build_sweep_grid(args)?;
    let cells = grid.expand();
    anyhow::ensure!(!cells.is_empty(), "sweep grid expanded to zero feasible cells");

    let t0 = std::time::Instant::now();
    let results = sweep::run_cells(&grid, &cells, threads);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "sweep: {} cells x {} threads in {:.2}s ({:.1} cells/s)",
        results.len(),
        threads,
        dt,
        results.len() as f64 / dt.max(1e-9)
    );

    if results.len() <= 64 {
        let mut table =
            Table::new(&["platform", "workload", "policy", "tile", "mode", "GFLOPS", "load %", "makespan s", "xfer MB"]);
        for r in &results {
            table.row(&[
                r.platform.clone(),
                r.workload.clone(),
                r.policy.clone(),
                r.tile.to_string(),
                r.mode.clone(),
                format!("{:.2}", r.gflops),
                format!("{:.1}", r.avg_load_pct),
                format!("{:.4}", r.makespan),
                format!("{:.1}", r.transfer_bytes as f64 / 1e6),
            ]);
        }
        table.print();
    } else {
        // large grid: print the per-(platform, workload, mode) winners
        let mut best: std::collections::BTreeMap<(String, String, String), &sweep::CellResult> =
            std::collections::BTreeMap::new();
        for r in &results {
            let k = (r.platform.clone(), r.workload.clone(), r.mode.clone());
            let e = best.entry(k).or_insert(r);
            if r.makespan < e.makespan {
                *e = r;
            }
        }
        let mut table = Table::new(&["platform", "workload", "mode", "best policy", "tile", "GFLOPS", "makespan s"]);
        for ((pf, wl, mode), r) in &best {
            table.row(&[
                pf.clone(),
                wl.clone(),
                mode.clone(),
                r.policy.clone(),
                r.tile.to_string(),
                format!("{:.2}", r.gflops),
                format!("{:.4}", r.makespan),
            ]);
        }
        println!("{} cells; per-(platform, workload, mode) winners:", results.len());
        table.print();
    }

    let out = std::path::PathBuf::from(args.str_or("out", "bench_out/sweep.csv"));
    if let Some(dir) = out.parent().filter(|d| !d.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&out, sweep::to_csv(&results))?;
    let json = out.with_extension("json");
    std::fs::write(&json, sweep::to_json(&results))?;
    println!("aggregate bundle -> {} + {}", out.display(), json.display());
    Ok(())
}

/// The policies a serve run compares by default: the strongest
/// job-oblivious baselines (fcfs/eft-p orders by task release, pl/eft-p by
/// per-job critical time) against the two job-aware orderings.
const SERVE_DEFAULT_POLICIES: [&str; 4] = ["fcfs/eft-p", "pl/eft-p", "pl/edf-p", "pl/sjf-p"];

/// Build the scenario grid for `hesp serve`: `--quick` (without a
/// platform) is the self-contained CI smoke grid; otherwise the grid
/// comes from flags.
fn build_serve_grid(args: &Args) -> Result<ServeGrid> {
    let reg = PolicyRegistry::standard();
    let cache = CachePolicy::from_name(&args.str_lower_or("cache", "wb")).ok_or_else(|| anyhow!("bad --cache"))?;
    let admission = Admission::parse(&args.str_lower_or("admission", "defer"))
        .ok_or_else(|| anyhow!("bad --admission (defer | reject)"))?;
    let queue_cap = args.usize_or("cap", 64);
    let seed = args.u64_or("seed", 0);
    let duration = args.f64_or("duration", 3.0);
    anyhow::ensure!(duration > 0.0, "--duration must be positive");

    // not get_lower: a trace:<path> spec must keep the path's case
    let arrivals: Vec<ArrivalSpec> = match args.get("arrivals") {
        Some(list) => {
            let mut out = Vec::new();
            for a in list.split(',') {
                let a = a.trim();
                out.push(
                    ArrivalSpec::parse(a)
                        .ok_or_else(|| anyhow!("bad arrival spec '{a}' (poisson:R | bursty:LO:HI:DWELL | trace:FILE)"))?,
                );
            }
            out
        }
        None if args.has("quick") => vec![
            ArrivalSpec::Poisson { rate: 8.0 },
            ArrivalSpec::Bursty { lo: 3.0, hi: 25.0, dwell: 0.15 },
        ],
        None => vec![ArrivalSpec::Poisson { rate: args.f64_or("rate", 8.0) }],
    };

    let policies: Vec<String> = if let Some(list) = args.get_lower("policies") {
        if list == "all" {
            reg.names().iter().map(|s| s.to_string()).collect()
        } else {
            let mut out = Vec::new();
            for name in list.split(',') {
                let name = name.trim();
                let pol = reg.resolve(name).map_err(|e| anyhow!(e))?;
                out.push(pol.name().to_string());
            }
            out
        }
    } else if let Some(name) = args.get_lower("policy") {
        let pol = reg.resolve(&name).map_err(|e| anyhow!(e))?;
        vec![pol.name().to_string()]
    } else {
        SERVE_DEFAULT_POLICIES.iter().map(|s| s.to_string()).collect()
    };

    let platforms = if args.has("quick") && !args.has("platform") && !args.has("platforms") {
        // the CI smoke grid: both reference platforms x 2 arrival
        // processes x 4 policies = 16 scenarios, run to drain
        vec![
            SweepPlatform::from_file("configs/bujaruelo.toml")?,
            SweepPlatform::from_file("configs/odroid.toml")?,
        ]
    } else if let Some(list) = args.get("platforms") {
        let mut out = Vec::new();
        for p in list.split(',') {
            out.push(SweepPlatform::from_file(p.trim())?);
        }
        out
    } else if let Some(p) = args.get("platform") {
        vec![SweepPlatform::from_file(p)?]
    } else {
        bail!("--platform F | --platforms F1,F2 required (or bare --quick)");
    };

    let max_defer = match args.get("max-defer") {
        None => None,
        Some(_) => {
            let v = args.f64_or("max-defer", 0.0);
            anyhow::ensure!(v > 0.0, "--max-defer must be a positive number of seconds");
            Some(v)
        }
    };
    let faults = faults_spec_flag(args)?;

    Ok(ServeGrid { platforms, arrivals, policies, duration, queue_cap, admission, cache, seed, max_defer, faults })
}

fn cmd_serve(args: &Args) -> Result<()> {
    let threads = args.usize_or("threads", sweep::default_threads());
    let grid = build_serve_grid(args)?;

    let t0 = std::time::Instant::now();
    let results = service::run_serve(&grid, threads)?;
    let dt = t0.elapsed().as_secs_f64();
    let total_jobs: usize = results.iter().map(|r| r.completed).sum();
    println!(
        "serve: {} scenarios x {} threads in {:.2}s ({} jobs simulated, {:.0} jobs/s)",
        results.len(),
        threads,
        dt,
        total_jobs,
        total_jobs as f64 / dt.max(1e-9)
    );

    let mut table = Table::new(&[
        "platform", "arrivals", "policy", "done", "rej", "thru j/s", "p50 s", "p99 s", "miss %", "fair", "load %",
    ]);
    for r in &results {
        table.row(&[
            r.platform.clone(),
            r.arrivals.clone(),
            r.policy.clone(),
            r.completed.to_string(),
            r.rejected.to_string(),
            format!("{:.2}", r.throughput_jps),
            format!("{:.4}", r.p50_sojourn),
            format!("{:.4}", r.p99_sojourn),
            format!("{:.1}", r.deadline_miss_pct),
            format!("{:.3}", r.fairness),
            format!("{:.1}", r.avg_load_pct),
        ]);
    }
    table.print();

    let out = std::path::PathBuf::from(args.str_or("out", "bench_out/serve.csv"));
    // the failure/expiry columns appear only when a knob that can
    // populate them is on, so plain bundles keep their exact bytes
    let ext = grid.faults.is_some() || grid.max_defer.is_some();
    let (csv, json) = service::write_serve_bundle(&out, &results, ext)?;
    println!("serve bundle -> {} + {}", csv.display(), json.display());

    // wall-clock record for the bench baseline — deliberately a separate
    // file, never part of the byte-compared bundle
    if let Some(bj) = args.get("bench-json") {
        use hesp::util::json::Json;
        let mut o = std::collections::BTreeMap::new();
        o.insert("name".into(), Json::Str("serve".into()));
        o.insert("scenarios".into(), Json::Num(results.len() as f64));
        o.insert("jobs".into(), Json::Num(total_jobs as f64));
        o.insert("threads".into(), Json::Num(threads as f64));
        o.insert("wall_s".into(), Json::Num(dt));
        o.insert("jobs_per_s".into(), Json::Num(total_jobs as f64 / dt.max(1e-9)));
        let path = std::path::PathBuf::from(bj);
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(&path, Json::Obj(o).to_string())?;
        println!("bench record -> {}", path.display());
    }
    Ok(())
}

fn solver_config(args: &Args, sim: SimConfig) -> Result<SolverConfig> {
    Ok(SolverConfig {
        candidates: CandidateSelect::from_name(&args.str_or("candidates", "all"))
            .ok_or_else(|| anyhow!("bad --candidates"))?,
        sampling: Sampling::from_name(&args.str_or("sampling", "soft")).ok_or_else(|| anyhow!("bad --sampling"))?,
        iters: args.usize_or("iters", 150),
        min_edge: args.usize_or("min-edge", 64) as u32,
        objective: Objective::from_name(&args.str_or("objective", "makespan"))
            .ok_or_else(|| anyhow!("bad --objective"))?,
        sim,
        seed: args.u64_or("seed", 0x5e5f),
        allow_merge: args.bool_or("merge", true),
    })
}

fn cmd_solve(args: &Args) -> Result<()> {
    // bare --quick (no platform): the self-contained determinism-smoke
    // cell CI runs at several thread counts and cmps byte-for-byte
    let quick = args.has("quick") && !args.has("platform");
    let p = if quick { Platform::from_file("configs/bujaruelo.toml")? } else { load_platform(args)? };
    let n = args.usize_or("n", if quick { 4096 } else { 32768 }) as u32;
    let tiles: Vec<u32> = args.usize_list("tiles", &default_tiles(n)).into_iter().map(|x| x as u32).collect();
    let sim = sim_config(args, &p)?;
    let mut scfg = solver_config(args, sim)?;
    if quick && !args.has("iters") {
        scfg.iters = 40;
    }
    let threads = args.usize_or("threads", sweep::default_threads());
    let lanes = args.usize_or("portfolio", if quick { 4 } else { 1 });
    let batch = args.usize_or("batch", if quick { 2 } else { 1 });
    let delta = delta_flag(args)?;
    let mut pol = build_policy(args, &p)?;
    let policy_name = pol.name().to_string();

    let (hb, hdag, hsched) =
        best_homogeneous_with(n, &tiles, &p.machine, &p.db, sim, scfg.objective, pol.as_mut())
            .ok_or_else(|| anyhow!("no legal tile size in {tiles:?} for n={n}"))?;
    print_report(&format!("best homogeneous (b={hb}, {policy_name})"), &hdag, &hsched);

    let faults = faults_spec_flag(args)?.map(|spec| FaultEnsemble::new(spec, fault_members_flag(args)));
    let pcfg = PortfolioConfig { base: scfg, batch, lanes, threads, lane_specs: Vec::new(), delta, faults };
    let reg = PolicyRegistry::standard();
    anyhow::ensure!(
        reg.get(&policy_name).is_some(),
        "policy '{policy_name}' is not registry-constructible; the portfolio solver needs a registered name"
    );
    let t0 = std::time::Instant::now();
    let res = solve_portfolio(&hdag, &p.machine, &p.db, &PartitionerSet::standard(), &reg, &policy_name, &pcfg);
    let dt = t0.elapsed().as_secs_f64();
    print_report(
        &format!("best heterogeneous (iter {}, lane {}/{lanes})", res.best_iter, res.lane),
        &res.best_dag,
        &res.best_schedule,
    );
    let imp = 100.0 * (hsched.makespan - res.best_schedule.makespan) / res.best_schedule.makespan;
    println!(
        "improvement: {imp:.2}%  ({lanes} lanes x {batch}-candidate batches x {} iters on {threads} threads, {dt:.2}s)",
        scfg.iters
    );
    if let Some(ens) = pcfg.faults.as_ref().filter(|e| !e.spec.is_empty()) {
        println!(
            "fault-aware objective: expected cost over {} members of '{}' = {:.6} (reported schedule is the nominal run)",
            ens.members, ens.spec.name, res.best_cost
        );
    }
    // replay counters live OUTSIDE the canonical solver JSON: stdout and
    // the --bench-json record are their only outlets, so the byte-compared
    // artifact stays identical across --delta modes
    let st = res.replay_stats();
    if delta.enabled() {
        println!(
            "delta[{}]: {:.1}% of events skipped via verified replay ({}/{} events, {} cache hits, {} full fallbacks)",
            delta.name(),
            100.0 * st.replay_fraction(),
            st.events_replayed,
            st.events_total,
            st.cache_hits,
            st.full_fallbacks
        );
    }

    if let Some(bj) = args.get("bench-json") {
        use hesp::util::json::Json;
        let evals: usize = res.history.iter().map(|h| h.evaluated).sum();
        let mut o = std::collections::BTreeMap::new();
        o.insert("name".into(), Json::Str("solve".into()));
        o.insert("n".into(), Json::Num(n as f64));
        o.insert("iters".into(), Json::Num(scfg.iters as f64));
        o.insert("lanes".into(), Json::Num(lanes as f64));
        o.insert("batch".into(), Json::Num(batch as f64));
        o.insert("threads".into(), Json::Num(threads as f64));
        o.insert("delta".into(), Json::Str(delta.name().into()));
        o.insert("wall_s".into(), Json::Num(dt));
        o.insert("candidate_evals".into(), Json::Num(evals as f64));
        o.insert("evals_per_s".into(), Json::Num(evals as f64 / dt.max(1e-9)));
        o.insert("events_replayed".into(), Json::Num(st.events_replayed as f64));
        o.insert("events_total".into(), Json::Num(st.events_total as f64));
        o.insert("cache_hits".into(), Json::Num(st.cache_hits as f64));
        o.insert("full_fallbacks".into(), Json::Num(st.full_fallbacks as f64));
        o.insert("replay_frac".into(), Json::Num(st.replay_fraction()));
        let path = std::path::PathBuf::from(bj);
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(&path, Json::Obj(o).to_string())?;
        println!("bench record -> {}", path.display());
    }

    if let Some(out) = args.get("out") {
        let path = std::path::PathBuf::from(out);
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(&path, result_json(&res))?;
        println!("canonical solver JSON -> {}", path.display());
    }
    Ok(())
}

fn cmd_online(args: &Args) -> Result<()> {
    use hesp::coordinator::constructive::{schedule_online_with, OnlineConfig};
    let p = load_platform(args)?;
    let n = args.usize_or("n", 32768) as u32;
    let b = args.usize_or("tile", 2048) as u32;
    let sim = sim_config(args, &p)?;
    let mut pol = build_policy(args, &p)?;
    let mut dag = cholesky::root(n);
    cholesky::partition_uniform(&mut dag, b);
    let base = simulate_policy(&dag, &p.machine, &p.db, sim, pol.as_mut());
    print_report(&format!("static uniform b={b} [{}]", pol.name()), &dag, &base);
    let mut cfg = OnlineConfig::new(sim, args.usize_or("min-edge", 128) as u32);
    cfg.gain_factor = args.f64_or("gain", 0.6);
    let res = schedule_online_with(&dag, &p.machine, &p.db, &PartitionerSet::standard(), cfg, pol.as_mut());
    print_report(&format!("constructive ({} online splits)", res.splits), &res.dag, &res.schedule);
    let imp = 100.0 * (base.makespan - res.schedule.makespan) / res.schedule.makespan;
    println!("improvement: {imp:.2}%");
    if args.has("gantt") {
        print!("{}", hesp::coordinator::trace::ascii_gantt(&res.dag, &res.schedule, &p.machine, 100));
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let p = load_platform(args)?;
    let n = args.usize_or("n", 32768) as u32;
    let tiles: Vec<u32> = args.usize_list("tiles", &default_tiles(n)).into_iter().map(|x| x as u32).collect();
    let iters = args.usize_or("iters", 150);
    let reg = PolicyRegistry::standard();
    let mut table = Table::new(&[
        "Policy", "Hom GFLOPS", "Hom load%", "Hom b", "Het GFLOPS", "Improve %", "Het load%", "Het avg b", "depth",
    ]);
    let sim = sim_config(args, &p)?;
    for name in reg.names() {
        let mut pol = reg.get(name).expect("registered policy constructs");
        let (hb, hdag, hsched) =
            best_homogeneous_with(n, &tiles, &p.machine, &p.db, sim, Objective::Makespan, pol.as_mut())
                .ok_or_else(|| anyhow!("no legal tiles"))?;
        let hr = report(&hdag, &hsched);
        let mut scfg = solver_config(args, sim)?;
        scfg.iters = iters;
        let res = solve_with(hdag, &p.machine, &p.db, &PartitionerSet::standard(), scfg, pol.as_mut());
        let er = report(&res.best_dag, &res.best_schedule);
        let imp = 100.0 * (er.gflops - hr.gflops) / hr.gflops;
        table.row(&[
            name.to_string(),
            format!("{:.2}", hr.gflops),
            format!("{:.1}", hr.avg_load_pct),
            hb.to_string(),
            format!("{:.2}", er.gflops),
            format!("{:.2}", imp),
            format!("{:.1}", er.avg_load_pct),
            format!("{:.1}", er.avg_block_size),
            er.dag_depth.to_string(),
        ]);
    }
    println!("Table 1 — {} (n={n}, f{}; 8 paper rows + policy extensions)", p.machine.name, p.elem_bytes * 8);
    table.print();
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    use hesp::coordinator::engine::simulate_mapped;
    use hesp::runtime::executor;

    let n = args.usize_or("n", 512) as u32;
    let tiles: Vec<u32> = args.usize_list("tiles", &[64, 128]).into_iter().map(|x| x as u32).collect();
    let reps = args.usize_or("reps", 3);
    let rt = executor::load_f32_runtime(&tiles)?;

    let local = Platform::from_file(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/local.toml"),
    )?;
    let mut table = Table::new(&["b", "real s", "sim-PM s", "sim-RD s", "PM err %", "RD err %", "max|LL^T-A|"]);
    for &b in &tiles {
        if n % b != 0 {
            continue;
        }
        let real = executor::run_cholesky(&rt, n, b, 42)?;
        anyhow::ensure!(real.max_err < 1e-2, "numerics check failed: {}", real.max_err);

        let measures = executor::measure_models(&rt, &[b], reps, 7)?;
        let rd_db = executor::measured_perfdb(&measures);

        let mut dag = cholesky::root(n);
        cholesky::partition_uniform(&mut dag, b);
        let frontier_len = dag.frontier().len();
        let mapping = vec![0usize; frontier_len]; // single local proc
        let sim = SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestIdle));
        let pm = simulate_mapped(&dag, &local.machine, &local.db, sim, &mapping);
        let rd = simulate_mapped(&dag, &local.machine, &rd_db, sim, &mapping);
        let pm_err = 100.0 * (pm.makespan - real.total_s) / real.total_s;
        let rd_err = 100.0 * (rd.makespan - real.total_s) / real.total_s;
        table.row(&[
            b.to_string(),
            format!("{:.3}", real.total_s),
            format!("{:.3}", pm.makespan),
            format!("{:.3}", rd.makespan),
            format!("{pm_err:+.1}"),
            format!("{rd_err:+.1}"),
            format!("{:.2e}", real.max_err),
        ]);
    }
    println!("Framework validation (real PJRT execution vs HESP-REPLICA), n={n}");
    table.print();
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    use hesp::runtime::executor;
    let tiles: Vec<u32> = args.usize_list("tiles", &[32, 64, 128]).into_iter().map(|x| x as u32).collect();
    let reps = args.usize_or("reps", 5);
    let rt = executor::load_f32_runtime(&tiles)?;
    let ms = executor::measure_models(&rt, &tiles, reps, 11)?;
    println!("# measured on this machine — paste into configs/local.toml");
    print!("{}", executor::measurements_to_toml(&ms));
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let p = load_platform(args)?;
    let n = args.usize_or("n", 32768) as u32;
    let b = args.usize_or("tile", 2048) as u32;
    let out = std::path::PathBuf::from(args.str_or("out", "bench_out/traces"));
    let sim = sim_config(args, &p)?;
    let mut pol = build_policy(args, &p)?;

    let mut dag = cholesky::root(n);
    cholesky::partition_uniform(&mut dag, b);
    let sched = simulate_policy(&dag, &p.machine, &p.db, sim, pol.as_mut());
    write_bundle(&out, &format!("{}_homog_b{b}", p.machine.name), &dag, &sched, &p.machine)?;
    print_report("homogeneous", &dag, &sched);

    let iters = args.usize_or("solve-iters", 150);
    let mut scfg = solver_config(args, sim)?;
    scfg.iters = iters;
    let res = solve_with(dag, &p.machine, &p.db, &PartitionerSet::standard(), scfg, pol.as_mut());
    write_bundle(&out, &format!("{}_heterog", p.machine.name), &res.best_dag, &res.best_schedule, &p.machine)?;
    print_report("heterogeneous", &res.best_dag, &res.best_schedule);
    println!("trace bundles in {}", out.display());
    Ok(())
}

fn cmd_dag(args: &Args) -> Result<()> {
    let n = args.usize_or("n", 16384) as u32;
    let b = args.usize_or("tile", 1024) as u32;
    if n % b != 0 {
        bail!("tile must divide n");
    }
    let mut dag = cholesky::root(n);
    cholesky::partition_uniform(&mut dag, b);
    let flat = dag.flat_dag();
    println!(
        "n={n} b={b}: {} tasks, {} edges, width {}, longest path {}",
        flat.len(),
        flat.edge_count(),
        flat.width(),
        flat.longest_path_len()
    );
    if let Some(out) = args.get("out") {
        std::fs::write(out, dag.to_dot())?;
        println!("DOT written to {out}");
    }
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    let root = match args.get("root") {
        Some(r) => std::path::PathBuf::from(r),
        None => hesp::analysis::default_root()?,
    };
    let report = hesp::analysis::lint_tree(&root)?;
    match args.get("json") {
        // bare `--json` prints the machine-readable report instead of the
        // human one; `--json FILE` writes it alongside the human report.
        Some("true") => println!("{}", report.to_json()),
        Some(path) => {
            std::fs::write(path, format!("{}\n", report.to_json()))?;
            print!("{}", report.render());
            println!("JSON written to {path}");
        }
        None => print!("{}", report.render()),
    }
    if report.unsuppressed() > 0 {
        bail!("{} unsuppressed finding(s)", report.unsuppressed());
    }
    Ok(())
}

fn cmd_check(args: &Args) -> Result<()> {
    let files: Vec<String> = if args.positional.len() > 1 {
        args.positional[1..].to_vec()
    } else {
        let root = match args.get("root") {
            Some(r) => std::path::PathBuf::from(r),
            None => hesp::analysis::default_root()?,
        };
        let files = hesp::analysis::default_check_files(&root);
        if files.is_empty() {
            bail!("no input files found under {} (pass FILES explicitly)", root.display());
        }
        files
    };
    let (mut errors, mut warnings) = (0usize, 0usize);
    for file in &files {
        for d in hesp::analysis::check::check_file(file) {
            println!("{}", d.render());
            if d.error {
                errors += 1;
            } else {
                warnings += 1;
            }
        }
    }
    println!("hesp check: {} file(s), {errors} error(s), {warnings} warning(s)", files.len());
    if errors > 0 {
        bail!("{errors} input error(s)");
    }
    Ok(())
}
