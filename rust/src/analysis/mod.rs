//! The detlint determinism & schedule-safety static analysis.
//!
//! Every headline result this repo produces rests on one invariant:
//! simulations are bit-reproducible for any `--threads` count. The
//! dynamic enforcement (the schedule-invariant oracle, 1-vs-4 `cmp`
//! smokes) only catches a violation if a CI grid happens to exercise it;
//! this module is the compile-time-style gate. Two passes:
//!
//! * **`hesp lint`** ([`lint_tree`]) — scans `src/` and `examples/` with
//!   the rule registry in [`rules`] (hash-map iteration order, wall-clock
//!   reads, unseeded RNG, float reductions over hash iterators, panics in
//!   input-parsing paths). Suppressions are explicit and reasoned:
//!   `// detlint: allow(<rule>) — <reason>`.
//! * **`hesp check`** ([`check`]) — statically validates simulation
//!   inputs (platform TOMLs, sweep grids, JSONL traces) before anything
//!   runs.
//!
//! Both produce deterministic, byte-stable output: stable '/'-separated
//! path labels, sorted findings, no timestamps.

pub mod check;
pub mod lexer;
pub mod report;
pub mod rules;

pub use report::{Finding, LintReport};

use std::path::{Path, PathBuf};

/// Lint a set of in-memory `(label, text)` pairs — the pure entry point
/// the CLI and the test harness share.
pub fn lint_files(files: &[(String, String)]) -> LintReport {
    let mut report = LintReport { files_scanned: files.len(), ..Default::default() };
    for (label, text) in files {
        let scanned = lexer::scan(label, text);
        let mut findings = rules::run_rules(&scanned);
        rules::apply_suppressions(&scanned, &mut findings);
        report.findings.extend(findings);
    }
    report.sort();
    report
}

/// Lint the source tree under `root` (the directory containing `src/`,
/// i.e. `rust/`). Files under `root/src` get `src/...` labels; the
/// sibling `examples/` directory (one level up, shared with the Python
/// layer docs), when present, gets `examples/...` labels.
pub fn lint_tree(root: &Path) -> anyhow::Result<LintReport> {
    let src = root.join("src");
    if !src.is_dir() {
        anyhow::bail!("no src/ under '{}' (pass --root <dir-containing-src>)", root.display());
    }
    let mut files = Vec::new();
    collect_rs_files(&src, "src", &mut files)?;
    let examples = root.join("..").join("examples");
    if examples.is_dir() {
        collect_rs_files(&examples, "examples", &mut files)?;
    }
    files.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(lint_files(&files))
}

/// Locate the lint/check root from the current directory: `.` when it
/// holds `src/`, else `rust/` (so the CLI works from either the crate
/// directory or the repository root).
pub fn default_root() -> anyhow::Result<PathBuf> {
    for cand in [".", "rust"] {
        let p = PathBuf::from(cand);
        if p.join("src").is_dir() {
            return Ok(p);
        }
    }
    anyhow::bail!("cannot find src/ from the current directory; pass --root <dir-containing-src>")
}

/// The shipped input files `hesp check` validates by default: every TOML
/// under `root/configs`, plus every TOML and JSONL under the sibling
/// `examples/` directory. Sorted for deterministic output.
pub fn default_check_files(root: &Path) -> Vec<String> {
    let mut out = Vec::new();
    let mut push_dir = |dir: PathBuf, exts: &[&str]| {
        let Ok(entries) = std::fs::read_dir(&dir) else { return };
        for e in entries.flatten() {
            let p = e.path();
            let ext = p.extension().and_then(|x| x.to_str()).unwrap_or("");
            if p.is_file() && exts.contains(&ext) {
                out.push(p.to_string_lossy().replace('\\', "/"));
            }
        }
    };
    push_dir(root.join("configs"), &["toml"]);
    push_dir(root.join("..").join("examples"), &["toml", "jsonl"]);
    out.sort();
    out
}

/// Recursively collect `.rs` files under `dir`, labeling them
/// `label_prefix/<relative path>` with '/' separators. The walk is
/// sorted, so labels (and therefore reports) are byte-stable across
/// platforms and runs.
fn collect_rs_files(
    dir: &Path,
    label_prefix: &str,
    out: &mut Vec<(String, String)>,
) -> anyhow::Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("").to_string();
        if p.is_dir() {
            collect_rs_files(&p, &format!("{label_prefix}/{name}"), out)?;
        } else if name.ends_with(".rs") {
            let text = std::fs::read_to_string(&p)
                .map_err(|e| anyhow::anyhow!("reading {}: {e}", p.display()))?;
            out.push((format!("{label_prefix}/{name}"), text));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lint_files_aggregates_and_sorts() {
        let files = vec![
            ("src/b.rs".to_string(), "fn f() { let t = std::time::Instant::now(); let _ = t; }\n".to_string()),
            ("src/a.rs".to_string(), "fn g() { let r = Rng::new(1); let _ = r; }\n".to_string()),
        ];
        let report = lint_files(&files);
        assert_eq!(report.files_scanned, 2);
        assert_eq!(report.findings.len(), 2);
        assert_eq!(report.findings[0].file, "src/a.rs");
        assert_eq!(report.findings[0].rule, "det/unseeded-rng");
        assert_eq!(report.findings[1].rule, "det/wall-clock");
    }
}
