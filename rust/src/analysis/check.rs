//! `hesp check` — the static input sanitizer.
//!
//! Validates simulation inputs *before* any simulation runs: platform
//! TOMLs (disconnected memory spaces, zero/negative-rate perf curves,
//! unreachable processor types), sweep-grid TOMLs (infeasible
//! tile/workload combos, empty expansions), fault-spec TOMLs (inverted
//! or negative fault windows, out-of-range transient rates), and JSONL
//! traces (non-monotonic arrivals, duplicate job ids, deadlines before
//! arrival). Every problem carries a precise `file:key` diagnostic; the
//! pass itself never panics and collects *all* problems instead of
//! stopping at the first — the validation hooks it calls
//! ([`crate::coordinator::platform::Machine::diagnostics`],
//! [`crate::coordinator::perfmodel::PerfDb::diagnostics`]) exist for
//! exactly this.

use crate::config::Platform;
use crate::coordinator::faults::FaultSpec;
use crate::coordinator::service::arrivals::{parse_trace_line, Deadline};
use crate::coordinator::sweep::grid_from_toml;

/// One sanitizer diagnostic, addressable as `file:key`.
#[derive(Debug, Clone)]
pub struct Diag {
    pub file: String,
    /// The offending config entity: `memory.gpu0_mem`, `perf.gpu.gemm`,
    /// `workloads.cholesky:8192`, `line 17`, ...
    pub key: String,
    /// `true` = error (nonzero exit), `false` = warning.
    pub error: bool,
    pub msg: String,
}

impl Diag {
    fn err(file: &str, key: impl Into<String>, msg: impl Into<String>) -> Diag {
        Diag { file: file.to_string(), key: key.into(), error: true, msg: msg.into() }
    }

    fn warn(file: &str, key: impl Into<String>, msg: impl Into<String>) -> Diag {
        Diag { file: file.to_string(), key: key.into(), error: false, msg: msg.into() }
    }

    pub fn render(&self) -> String {
        let sev = if self.error { "error" } else { "warning" };
        format!("{}:{}: {sev}: {}", self.file, self.key, self.msg)
    }
}

/// Validate a platform TOML.
pub fn check_platform_text(file: &str, text: &str) -> Vec<Diag> {
    let platform = match Platform::from_str_unchecked(text) {
        Ok(p) => p,
        Err(e) => return vec![Diag::err(file, "parse", format!("{e:#}"))],
    };
    let mut out = Vec::new();
    let m = &platform.machine;
    for (key, msg) in m.diagnostics() {
        out.push(Diag::err(file, key, msg));
    }
    for (key, msg) in platform.db.diagnostics(m) {
        out.push(Diag::err(file, key, msg));
    }
    if platform.elem_bytes == 0 {
        out.push(Diag::err(file, "elem_bytes", "elem_bytes must be positive"));
    }
    for pt in &m.proc_types {
        if !m.procs.iter().any(|p| p.ptype == pt.id) {
            out.push(Diag::warn(
                file,
                format!("proctype.{}", pt.name),
                "no [[processor]] instantiates this type — its perf model is dead weight",
            ));
        }
    }
    for s in &m.spaces {
        if s.capacity == 0 {
            out.push(Diag::err(
                file,
                format!("memory.{}", s.name),
                "zero-byte capacity: no block ever fits this space",
            ));
        }
    }
    out
}

/// Validate a sweep-grid TOML. Platform paths inside the grid resolve
/// relative to the current directory, exactly as `hesp sweep` resolves
/// them.
pub fn check_grid_text(file: &str, text: &str) -> Vec<Diag> {
    let grid = match grid_from_toml(text) {
        Ok(g) => g,
        Err(e) => return vec![Diag::err(file, "parse", format!("{e:#}"))],
    };
    let mut out = Vec::new();
    for w in &grid.workloads {
        if !grid.tiles.iter().any(|&b| w.feasible(b)) {
            out.push(Diag::err(
                file,
                format!("workloads.{}", w.label()),
                format!("no feasible tile for this workload among tiles = {:?}", grid.tiles),
            ));
        }
    }
    if grid.expand().is_empty() {
        out.push(Diag::err(file, "grid", "grid expands to zero cells"));
    }
    out
}

/// Validate a fault-spec TOML (`kind = "faults"`). Shape problems only:
/// processor and link indices are range-checked against a machine at
/// install time, because a spec file is platform-independent.
pub fn check_faults_text(file: &str, text: &str) -> Vec<Diag> {
    let spec = match FaultSpec::from_toml(text) {
        Ok(s) => s,
        Err(e) => return vec![Diag::err(file, "parse", e)],
    };
    let mut out = Vec::new();
    for (key, msg) in spec.diagnostics() {
        out.push(Diag::err(file, key, msg));
    }
    if spec.is_empty() {
        out.push(Diag::warn(
            file,
            "spec",
            "no fault source is active — simulation with this spec is identical to --faults off",
        ));
    }
    out
}

/// Validate a JSONL trace. Unlike
/// [`crate::coordinator::service::arrivals::parse_trace`] (which stops at
/// the first malformed line), this collects a diagnostic per line and
/// keeps going.
pub fn check_trace_text(file: &str, text: &str) -> Vec<Diag> {
    let mut out = Vec::new();
    let mut declared: Vec<(usize, usize)> = Vec::new();
    let mut prev_arrival: Option<(f64, usize)> = None;
    let mut jobs = 0usize;
    for (i, line) in text.lines().enumerate() {
        let lineno = i + 1;
        let (job, id) = match parse_trace_line(lineno, line) {
            Ok(None) => continue,
            Ok(Some(parsed)) => parsed,
            Err(e) => {
                out.push(Diag::err(file, format!("line {lineno}"), format!("{e:#}")));
                continue;
            }
        };
        jobs += 1;
        if let Some(id) = id {
            if let Some(&(_, first)) = declared.iter().find(|&&(d, _)| d == id) {
                out.push(Diag::err(
                    file,
                    format!("line {lineno}"),
                    format!("duplicate job id {id} (first declared on line {first})"),
                ));
            } else {
                declared.push((id, lineno));
            }
        }
        if let Some((prev, prev_line)) = prev_arrival {
            if job.t_arrival < prev {
                out.push(Diag::warn(
                    file,
                    format!("line {lineno}"),
                    format!(
                        "t_arrival {} is earlier than line {prev_line}'s {prev}: replay re-sorts, but the trace is not in arrival order",
                        job.t_arrival
                    ),
                ));
            }
        }
        prev_arrival = Some((job.t_arrival, lineno));
        // `parse_trace_line` validated At-deadlines against arrival; the
        // Deadline::Slack form never appears in traces, so nothing more
        // to check here — but keep the exhaustive match so a new variant
        // forces a decision.
        match job.deadline {
            Deadline::None | Deadline::At(_) | Deadline::Slack(_) => {}
        }
    }
    if jobs == 0 {
        out.push(Diag::err(file, "trace", "trace contains no jobs"));
    }
    out
}

/// Sniff a file's kind and validate it: `.jsonl` files are traces, TOML
/// documents with `kind = "faults"` are fault specs, documents with a
/// top-level `platforms` key are sweep grids, everything else is a
/// platform.
pub fn check_file(path: &str) -> Vec<Diag> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => return vec![Diag::err(path, "read", e.to_string())],
    };
    check_text(path, &text)
}

/// [`check_file`] on already-loaded text (test entry point).
pub fn check_text(path: &str, text: &str) -> Vec<Diag> {
    if path.ends_with(".jsonl") {
        check_trace_text(path, text)
    } else if is_faults(text) {
        check_faults_text(path, text)
    } else if is_grid(text) {
        check_grid_text(path, text)
    } else {
        check_platform_text(path, text)
    }
}

/// A TOML document is a sweep grid iff it has a top-level `platforms` key.
fn is_grid(text: &str) -> bool {
    matches!(crate::util::toml::parse(text), Ok(doc) if doc.get("platforms").is_some())
}

/// A TOML document is a fault spec iff it declares `kind = "faults"`.
fn is_faults(text: &str) -> bool {
    matches!(
        crate::util::toml::parse(text),
        Ok(doc) if doc.get("kind").and_then(|v| v.as_str()) == Some("faults")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD_PLATFORM: &str = r#"
name = "toy"
main_space = "host"

[[memory]]
name = "host"

[[memory]]
name = "dev"
capacity_gb = 4.0

[[link]]
from = "host"
to = "dev"
latency_us = 10.0
bandwidth_gbs = 12.0

[[proctype]]
name = "cpu"

[perf.cpu.default]
gflops = 50.0

[[processor]]
prefix = "c"
count = 2
type = "cpu"
space = "host"
"#;

    #[test]
    fn good_platform_is_clean() {
        let diags = check_platform_text("p.toml", GOOD_PLATFORM);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn disconnected_space_is_reported_by_key() {
        let text = GOOD_PLATFORM.replace(
            "[[link]]\nfrom = \"host\"\nto = \"dev\"\nlatency_us = 10.0\nbandwidth_gbs = 12.0\n",
            "",
        );
        let diags = check_platform_text("p.toml", &text);
        assert!(
            diags.iter().any(|d| d.error && d.key == "memory.dev" && d.msg.contains("disconnected")),
            "{diags:?}"
        );
    }

    #[test]
    fn zero_rate_curve_is_reported() {
        let text = GOOD_PLATFORM.replace("gflops = 50.0", "gflops = 0.0");
        let diags = check_platform_text("p.toml", &text);
        assert!(
            diags.iter().any(|d| d.error && d.key == "perf.cpu.default" && d.msg.contains("non-positive rate")),
            "{diags:?}"
        );
    }

    #[test]
    fn unreachable_proctype_is_a_warning() {
        let extra = concat!(
            "\n[[proctype]]\nname = \"gpu\"\n\n[perf.gpu.default]\ngflops = 900.0\n"
        );
        let text = format!("{GOOD_PLATFORM}{extra}");
        let diags = check_platform_text("p.toml", &text);
        assert!(
            diags.iter().any(|d| !d.error && d.key == "proctype.gpu"),
            "{diags:?}"
        );
        assert!(diags.iter().all(|d| !d.error), "warnings only: {diags:?}");
    }

    #[test]
    fn trace_checks_collect_everything() {
        let text = concat!(
            "{\"t_arrival\": 1.0, \"workload\": \"cholesky:1024\", \"tile\": 256, \"id\": 1}\n",
            "{\"t_arrival\": 0.5, \"workload\": \"cholesky:1024\", \"tile\": 256, \"id\": 1}\n",
            "{\"t_arrival\": 2.0, \"workload\": \"nope\", \"tile\": 256}\n",
        );
        let diags = check_trace_text("t.jsonl", text);
        assert!(diags.iter().any(|d| d.error && d.key == "line 2" && d.msg.contains("duplicate job id 1")));
        assert!(diags.iter().any(|d| !d.error && d.key == "line 2" && d.msg.contains("earlier")));
        assert!(diags.iter().any(|d| d.error && d.key == "line 3"), "{diags:?}");
    }

    #[test]
    fn empty_trace_is_an_error() {
        let diags = check_trace_text("t.jsonl", "\n\n");
        assert!(diags.iter().any(|d| d.error && d.msg.contains("no jobs")));
    }

    #[test]
    fn fault_spec_sniffing_and_diagnostics() {
        let good = concat!(
            "kind = \"faults\"\nname = \"quick\"\n\n[transient]\nrate = 0.05\n\n",
            "[[throttle]]\nproc = 0\nfrom = 0.002\nto = 0.006\nfactor = 0.5\n",
        );
        assert!(is_faults(good));
        assert!(!is_faults(GOOD_PLATFORM));
        assert!(check_faults_text("f.toml", good).is_empty(), "{:?}", check_faults_text("f.toml", good));
        // check_text must route on the kind marker, not the file name
        assert!(check_text("f.toml", good).is_empty());

        // an inverted throttle window is rejected at parse time with the
        // offending key in the message
        let bad = good.replace("to = 0.006", "to = 0.001");
        let diags = check_faults_text("f.toml", &bad);
        assert!(
            diags.iter().any(|d| d.error && d.key == "parse" && d.msg.contains("throttle.0")),
            "{diags:?}"
        );

        // a structurally valid but fault-free spec gets a warning: it is
        // indistinguishable from --faults off
        let empty = "kind = \"faults\"\nname = \"noop\"\n";
        let diags = check_faults_text("f.toml", empty);
        assert!(diags.iter().any(|d| !d.error && d.key == "spec"), "{diags:?}");
        assert!(diags.iter().all(|d| !d.error), "warnings only: {diags:?}");
    }

    #[test]
    fn grid_sniffing_and_infeasible_tiles() {
        // A grid whose only workload can never meet its tiles: cholesky
        // needs n % b == 0 with at least a 2x2 tiling.
        let dir = std::env::temp_dir().join("hesp_check_grid_test");
        std::fs::create_dir_all(&dir).unwrap();
        let plat = dir.join("p.toml");
        std::fs::write(&plat, GOOD_PLATFORM).unwrap();
        let grid = format!(
            "platforms = [\"{}\"]\nworkloads = [\"cholesky:1000\"]\npolicies = [\"pl/eft-p\"]\ntiles = [256]\n",
            plat.display()
        );
        assert!(is_grid(&grid));
        assert!(!is_grid(GOOD_PLATFORM));
        let diags = check_grid_text("g.toml", &grid);
        assert!(
            diags.iter().any(|d| d.error && d.key == "workloads.cholesky:1000"),
            "{diags:?}"
        );
        assert!(diags.iter().any(|d| d.error && d.key == "grid"), "zero cells: {diags:?}");
    }
}
