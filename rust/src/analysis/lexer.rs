//! Line-level Rust source scanner for the `detlint` pass.
//!
//! Not a real Rust lexer — a deliberately small character state machine
//! that is *just* accurate enough for line-level rules: it blanks string,
//! raw-string, char and comment contents (so rule tokens never match
//! inside literals), splits out per-line comment text (so suppression
//! pragmas can be read back), and marks `#[cfg(test)]` regions by brace
//! counting (so test-only code is exempt from determinism rules). The
//! rules in [`super::rules`] then work on the blanked `code` of each line
//! with token-boundary matching.

/// One scanned source line.
#[derive(Debug, Clone)]
pub struct Line {
    /// 1-based line number.
    pub number: usize,
    /// Code text with comment bodies and string/char contents replaced by
    /// spaces (delimiters are kept, so `.expect("` stays matchable).
    pub code: String,
    /// Comment text on this line (bodies of `//` and `/* */` comments).
    pub comment: String,
    /// Whether the line sits inside a `#[cfg(test)]` item.
    pub in_test: bool,
}

/// A suppression pragma: `// detlint: allow(<rule>) — <reason>`.
/// It silences findings of `rule` on its own line and the next one.
#[derive(Debug, Clone)]
pub struct Pragma {
    pub line: usize,
    pub rule: String,
    pub reason: String,
}

/// A scanned source file: path label + lines + extracted pragmas.
#[derive(Debug, Clone)]
pub struct SourceFile {
    /// Stable, '/'-separated path label (e.g. `src/coordinator/sweep.rs`).
    pub path: String,
    pub lines: Vec<Line>,
    pub pragmas: Vec<Pragma>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum State {
    Code,
    LineComment,
    /// Nested block comments with the current depth.
    BlockComment(u32),
    Str,
    /// Raw string with the number of `#` marks in its delimiter.
    RawStr(u32),
}

/// Scan `text` into per-line code/comment channels.
pub fn scan(path: &str, text: &str) -> SourceFile {
    let chars: Vec<char> = text.chars().collect();
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut state = State::Code;
    let mut i = 0usize;

    let flush = |code: &mut String, comment: &mut String, lines: &mut Vec<Line>| {
        lines.push(Line {
            number: lines.len() + 1,
            code: std::mem::take(code),
            comment: std::mem::take(comment),
            in_test: false,
        });
    };

    while i < chars.len() {
        let c = chars[i];
        let next = chars.get(i + 1).copied();
        if c == '\n' {
            if state == State::LineComment {
                state = State::Code;
            }
            flush(&mut code, &mut comment, &mut lines);
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                if c == '/' && next == Some('/') {
                    state = State::LineComment;
                    code.push_str("  ");
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    state = State::BlockComment(1);
                    code.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    state = State::Str;
                    code.push('"');
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_is_ident(&chars, i) {
                    // raw / byte-string starts: r", r#", br", b"
                    let mut j = i + 1;
                    if c == 'b' && chars.get(j) == Some(&'r') {
                        j += 1;
                    }
                    let raw = j > i + 1 || c == 'r';
                    let mut hashes = 0u32;
                    while raw && chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if raw && chars.get(j) == Some(&'"') {
                        for _ in i..j {
                            code.push(' ');
                        }
                        code.push('"');
                        state = State::RawStr(hashes);
                        i = j + 1;
                    } else if c == 'b' && chars.get(i + 1) == Some(&'"') {
                        code.push(' ');
                        code.push('"');
                        state = State::Str;
                        i += 2;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // char literal vs lifetime
                    let is_char_lit = match next {
                        Some('\\') => true,
                        Some(_) => chars.get(i + 2) == Some(&'\''),
                        None => false,
                    };
                    if is_char_lit {
                        code.push('\'');
                        i += 1;
                        if chars.get(i) == Some(&'\\') {
                            // escaped char: skip to the closing quote
                            i += 1; // the backslash
                            if i < chars.len() {
                                i += 1; // the escaped char
                            }
                            while i < chars.len() && chars[i] != '\'' && chars[i] != '\n' {
                                i += 1;
                            }
                        } else if i < chars.len() {
                            i += 1; // the single char
                        }
                        code.push(' ');
                        if chars.get(i) == Some(&'\'') {
                            code.push('\'');
                            i += 1;
                        }
                    } else {
                        code.push('\'');
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                comment.push(c);
                i += 1;
            }
            State::BlockComment(depth) => {
                if c == '/' && next == Some('*') {
                    state = State::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    state = if depth == 1 { State::Code } else { State::BlockComment(depth - 1) };
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    code.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    state = State::Code;
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' && closes_raw(&chars, i, hashes) {
                    code.push('"');
                    for _ in 0..hashes {
                        code.push(' ');
                    }
                    state = State::Code;
                    i += 1 + hashes as usize;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        flush(&mut code, &mut comment, &mut lines);
    }

    mark_test_regions(&mut lines);
    let pragmas = extract_pragmas(&lines);
    SourceFile { path: path.to_string(), lines, pragmas }
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
}

fn closes_raw(chars: &[char], i: usize, hashes: u32) -> bool {
    (1..=hashes as usize).all(|k| chars.get(i + k) == Some(&'#'))
}

/// Mark every line inside a `#[cfg(test)]` item by brace counting: the
/// attribute arms a pending flag, the next `{` opens the region, and the
/// matching `}` closes it.
fn mark_test_regions(lines: &mut [Line]) {
    let mut depth = 0i64;
    let mut pending = false;
    let mut region_depth: Option<i64> = None;
    for line in lines.iter_mut() {
        if line.code.contains("#[cfg(test)]") {
            pending = true;
        }
        let mut touched = region_depth.is_some() || pending;
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    if pending {
                        region_depth = Some(depth);
                        pending = false;
                        touched = true;
                    }
                }
                '}' => {
                    if region_depth == Some(depth) {
                        region_depth = None;
                        touched = true;
                    }
                    depth -= 1;
                }
                _ => {}
            }
        }
        line.in_test = touched || region_depth.is_some();
    }
}

/// Extract `detlint: allow(<rule>) — <reason>` pragmas from comment text.
/// A pragma must be a dedicated comment: the comment body has to *start*
/// with `detlint:` (so prose that merely mentions the syntax is ignored).
/// A pragma with a malformed body gets `rule` set to the empty string;
/// [`super::rules`] reports those as `lint/bare-allow`.
fn extract_pragmas(lines: &[Line]) -> Vec<Pragma> {
    let mut out = Vec::new();
    for line in lines {
        let body = line.comment.trim_start_matches(['/', '!', '*', ' ', '\t']);
        let Some(rest) = body.strip_prefix("detlint:") else { continue };
        let rest = rest.trim_start();
        let parsed = rest.strip_prefix("allow(").and_then(|r| {
            let close = r.find(')')?;
            let rule = r[..close].trim().to_string();
            let reason = r[close + 1..]
                .trim_start()
                .trim_start_matches(['\u{2014}', '\u{2013}', '-', ':'])
                .trim()
                .to_string();
            if rule.is_empty() {
                None
            } else {
                Some((rule, reason))
            }
        });
        match parsed {
            Some((rule, reason)) => out.push(Pragma { line: line.number, rule, reason }),
            None => out.push(Pragma { line: line.number, rule: String::new(), reason: String::new() }),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked() {
        let f = scan("t.rs", "let x = \"HashMap inside\"; // Instant::now in comment\n");
        assert!(!f.lines[0].code.contains("HashMap"));
        assert!(!f.lines[0].code.contains("Instant"));
        assert!(f.lines[0].comment.contains("Instant::now"));
        assert!(f.lines[0].code.contains("let x = \""));
    }

    #[test]
    fn raw_strings_and_char_literals() {
        let f = scan("t.rs", "let r = r#\"panic!(\"x\")\"#;\nlet c = '\\n';\nlet l: &'static str = \"\";\n");
        assert!(!f.lines[0].code.contains("panic!"));
        assert!(f.lines[1].code.contains("let c = "));
        assert!(f.lines[2].code.contains("&'static str"), "{:?}", f.lines[2].code);
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let f = scan("t.rs", "a /* x /* y */ still */ b\n/* open\nunwrap()\n*/ c\n");
        assert!(f.lines[0].code.contains('a') && f.lines[0].code.contains('b'));
        assert!(!f.lines[2].code.contains("unwrap"));
        assert!(f.lines[3].code.contains('c'));
    }

    #[test]
    fn cfg_test_regions_are_marked() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\nfn after() {}\n";
        let f = scan("t.rs", src);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[1].in_test && f.lines[2].in_test && f.lines[3].in_test && f.lines[4].in_test);
        assert!(!f.lines[5].in_test);
    }

    #[test]
    fn pragmas_parse_with_and_without_reason() {
        let src = "x(); // detlint: allow(det/wall-clock) — bench timing only\ny(); // detlint: allow(det/unseeded-rng)\n";
        let f = scan("t.rs", src);
        assert_eq!(f.pragmas.len(), 2);
        assert_eq!(f.pragmas[0].rule, "det/wall-clock");
        assert_eq!(f.pragmas[0].reason, "bench timing only");
        assert_eq!(f.pragmas[1].rule, "det/unseeded-rng");
        assert!(f.pragmas[1].reason.is_empty());
    }
}
