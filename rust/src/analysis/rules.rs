//! The detlint rule registry.
//!
//! Each rule is a line-level check over the blanked code channel produced
//! by [`super::lexer`]. Rules are deliberately conservative heuristics:
//! they aim to catch the determinism hazards that matter for this repo's
//! bit-reproducibility invariant (hash-map iteration order, process-keyed
//! std hashers near checkpoint/signature code, wall-clock reads, unseeded
//! RNG construction, float reductions over hash iterators, and panics in
//! input-parsing paths) with token-boundary
//! matching so e.g. `FxHashMap` never matches a bare `HashMap` token.
//!
//! Suppression: `// detlint: allow(<rule>) — <reason>` on the finding's
//! line or the line directly above silences it. A pragma without a
//! written reason is itself a finding (`lint/bare-allow`) and cannot be
//! suppressed.

use super::lexer::SourceFile;
use super::report::Finding;
use std::collections::BTreeSet;

/// Hash-container type names whose iteration order is either randomized
/// (std) or insertion-dependent (Fx) — both hazards for reproducibility.
const HASH_TYPES: [&str; 4] = ["HashMap", "HashSet", "FxHashMap", "FxHashSet"];

/// Method suffixes that iterate a hash container.
const ITER_SUFFIXES: [&str; 9] = [
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".into_iter()",
    ".into_keys()",
    ".into_values()",
    ".drain(",
];

/// Float-reduction suffixes that, combined with hash iteration, yield
/// order-dependent floating-point results.
const REDUCE_TOKENS: [&str; 4] = [".sum()", ".sum::<", ".fold(", ".product("];

/// Files allowed to read the wall clock (timing shims and the executor's
/// real-time mode; simulated time lives elsewhere).
const WALL_CLOCK_EXEMPT: [&str; 3] = ["src/bench.rs", "src/main.rs", "src/runtime/executor.rs"];

/// Library input-parsing paths where a panic is a bug, not a contract:
/// malformed user input must surface as `Result`, never abort the
/// process (`hesp serve` keeps running across bad trace lines).
const PANIC_SCOPE: [&str; 6] = [
    "src/config.rs",
    "src/util/toml.rs",
    "src/util/json.rs",
    "src/util/cli.rs",
    "src/coordinator/sweep.rs",
    "src/coordinator/service/arrivals.rs",
];

/// Randomized-hasher type names. Checkpoint and frontier-signature
/// hashing in coordinator/ must go through the repo's FxHash shim:
/// std's SipHash is keyed per-process, so a `DefaultHasher` signature
/// would differ between the run that wrote a checkpoint and the run
/// that probes for it — a silent cache-miss storm at best, a
/// cross-process golden-trace mismatch at worst.
const RANDOM_HASHERS: [&str; 3] = ["DefaultHasher", "RandomState", "SipHasher13"];

/// All rule ids, for documentation and pragma validation.
pub const RULES: [&str; 8] = [
    "det/hashmap-iter",
    "det/checkpoint-hash",
    "det/wall-clock",
    "det/unseeded-rng",
    "det/float-reduce",
    "det/partial-cmp-unwrap",
    "safety/panic-in-lib",
    "lint/bare-allow",
];

/// True if `c` can be part of an identifier.
fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Find `token` in `code` at an identifier boundary: the characters
/// adjacent to the token's identifier-shaped ends must not be identifier
/// characters. Returns all match offsets.
fn find_token(code: &str, token: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let bytes = code.as_bytes();
    let first_is_ident = token.chars().next().is_some_and(is_ident);
    let last_is_ident = token.chars().last().is_some_and(is_ident);
    let mut from = 0;
    while let Some(rel) = code[from..].find(token) {
        let at = from + rel;
        let ok_before = !first_is_ident
            || at == 0
            || !is_ident(bytes[at - 1] as char);
        let end = at + token.len();
        let ok_after = !last_is_ident
            || end >= bytes.len()
            || !is_ident(bytes[end] as char);
        if ok_before && ok_after {
            out.push(at);
        }
        from = at + 1;
    }
    out
}

fn has_token(code: &str, token: &str) -> bool {
    !find_token(code, token).is_empty()
}

/// Collect names bound to hash-container types in this file: type
/// ascriptions (`name: FxHashMap<..>` / `name: Vec<FxHashMap<..>>`
/// struct fields, lets, params) and constructor bindings
/// (`name = FxHashMap::default()`).
fn collect_hash_names(file: &SourceFile) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for line in &file.lines {
        for ty in HASH_TYPES {
            for at in find_token(&line.code, ty) {
                if let Some(name) = binding_name_before(&line.code, at) {
                    names.insert(name);
                }
            }
        }
    }
    names
}

/// Walk backwards from a hash-type token over its qualified-path prefix
/// (`std::collections::`), then recognise either a type ascription
/// (`name: <path>`) or an assignment (`name = <path>::new()`), returning
/// the bound name. Returns `None` for `use` lines and bare mentions.
fn binding_name_before(code: &str, tok_start: usize) -> Option<String> {
    let chars: Vec<char> = code.chars().collect();
    // find_token offsets are byte offsets; the blanked code is ASCII-safe
    // for the regions we inspect, but convert defensively.
    let mut i = code[..tok_start].chars().count();
    // Skip the qualified-path prefix: `ident::ident::` sequences.
    loop {
        if i >= 2 && chars[i - 1] == ':' && chars[i - 2] == ':' {
            i -= 2;
            while i > 0 && is_ident(chars[i - 1]) {
                i -= 1;
            }
        } else {
            break;
        }
    }
    // Skip reference sigils and `mut` so `m: &FxHashMap<..>` and
    // `m: &mut FxHashMap<..>` both bind `m`.
    loop {
        while i > 0 && matches!(chars[i - 1], ' ' | '&') {
            i -= 1;
        }
        if i >= 3
            && chars[i - 3..i] == ['m', 'u', 't']
            && (i == 3 || !is_ident(chars[i - 4]))
        {
            i -= 3;
        } else {
            break;
        }
    }
    if i == 0 {
        return None;
    }
    let sep = chars[i - 1];
    if sep == ':' {
        // Must be a single-colon ascription, not a path `::`.
        if i >= 2 && chars[i - 2] == ':' {
            return None;
        }
        i -= 1;
    } else if sep == '=' {
        // Assignment `name = FxHashMap::default()`; reject `==`, `=>`,
        // `+=` and friends.
        if i >= 2 && !matches!(chars[i - 2], ' ' | '\t') {
            return None;
        }
        i -= 1;
    } else {
        return None;
    }
    while i > 0 && chars[i - 1] == ' ' {
        i -= 1;
    }
    let end = i;
    while i > 0 && is_ident(chars[i - 1]) {
        i -= 1;
    }
    if i == end {
        return None;
    }
    let name: String = chars[i..end].iter().collect();
    if name.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        return None;
    }
    // `mut` / `let` / keywords are not binding names.
    if matches!(name.as_str(), "mut" | "let" | "pub" | "ref" | "in" | "if") {
        return None;
    }
    Some(name)
}

/// True if `code` iterates `name` as a hash container: either
/// `name<iter-suffix>` or `for .. in [&|mut |self.]name` followed by a
/// non-identifier, non-`.` character (so `for x in name.lookup()` does
/// not count the receiver).
fn iterates(code: &str, name: &str) -> bool {
    for at in find_token(code, name) {
        let after = &code[at + name.len()..];
        if ITER_SUFFIXES.iter().any(|s| after.starts_with(s)) {
            return true;
        }
    }
    if let Some(pos) = code.find(" in ") {
        if has_token(&code[..pos + 3], "for") {
            let mut rest = code[pos + 4..].trim_start();
            loop {
                if let Some(r) = rest.strip_prefix('&') {
                    rest = r.trim_start();
                } else if let Some(r) = rest.strip_prefix("mut ") {
                    rest = r.trim_start();
                } else if let Some(r) = rest.strip_prefix("self.") {
                    rest = r;
                } else {
                    break;
                }
            }
            if let Some(r) = rest.strip_prefix(name) {
                let next = r.chars().next();
                if next.is_none_or(|c| !is_ident(c) && c != '.') {
                    return true;
                }
            }
        }
    }
    false
}

/// Run every rule over one scanned file, producing raw findings (before
/// suppression) sorted by line.
pub fn run_rules(file: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    let in_coordinator = file.path.starts_with("src/coordinator/");
    let wall_clock_exempt = WALL_CLOCK_EXEMPT.iter().any(|f| file.path == *f);
    let panic_scope = PANIC_SCOPE.iter().any(|f| file.path == *f);
    let hash_names = collect_hash_names(file);

    for line in &file.lines {
        if line.in_test {
            continue;
        }
        let code = line.code.as_str();

        // det/hashmap-iter: iteration over hash containers in coordinator/.
        if in_coordinator {
            for name in &hash_names {
                if iterates(code, name) {
                    out.push(Finding::new(
                        &file.path,
                        line.number,
                        "det/hashmap-iter",
                        format!(
                            "iteration over hash container `{name}` — order is not deterministic; sort first or use BTreeMap/Vec"
                        ),
                    ));
                    break;
                }
            }
        }

        // det/checkpoint-hash: process-keyed std hashers in coordinator/.
        if in_coordinator {
            for ty in RANDOM_HASHERS {
                if has_token(code, ty) {
                    out.push(Finding::new(
                        &file.path,
                        line.number,
                        "det/checkpoint-hash",
                        format!(
                            "`{ty}` is keyed per-process — checkpoint/signature hashes must use util::fxhash so identical states hash identically across runs"
                        ),
                    ));
                    break;
                }
            }
        }

        // det/float-reduce: float reduction chained onto hash iteration.
        let hash_iterated = hash_names.iter().any(|n| {
            find_token(code, n).iter().any(|&at| {
                let after = &code[at + n.len()..];
                ITER_SUFFIXES.iter().any(|s| after.starts_with(s))
            })
        });
        if hash_iterated && REDUCE_TOKENS.iter().any(|t| code.contains(t)) {
            out.push(Finding::new(
                &file.path,
                line.number,
                "det/float-reduce",
                "float reduction over a hash-container iterator — summation order varies; collect and sort first".to_string(),
            ));
        }

        // det/partial-cmp-unwrap: float comparators built by unwrapping
        // `partial_cmp` panic on the first NaN metric that reaches a
        // sort. Scoped to coordinator/, where every sort feeds the
        // bit-reproducible schedule/trace pipeline.
        if in_coordinator && has_token(code, "partial_cmp") && code.contains(".unwrap(") {
            out.push(Finding::new(
                &file.path,
                line.number,
                "det/partial-cmp-unwrap",
                "partial_cmp().unwrap() panics on NaN — use f64::total_cmp (or Ord::cmp on the non-float part) instead"
                    .to_string(),
            ));
        }

        // det/wall-clock: real-time reads outside timing shims.
        if !wall_clock_exempt {
            if code.contains("Instant::now") && has_token(code, "Instant") {
                out.push(Finding::new(
                    &file.path,
                    line.number,
                    "det/wall-clock",
                    "Instant::now() read — simulated components must use virtual time".to_string(),
                ));
            } else if has_token(code, "SystemTime") {
                out.push(Finding::new(
                    &file.path,
                    line.number,
                    "det/wall-clock",
                    "SystemTime read — simulated components must use virtual time".to_string(),
                ));
            }
        }

        // det/unseeded-rng: RNG construction not derived from a content
        // seed. Heuristic: the constructing line must mention a seed.
        let lower = code.to_ascii_lowercase();
        if (code.contains("Rng::new(") && has_token(code, "Rng") && !lower.contains("seed"))
            || has_token(code, "thread_rng")
            || has_token(code, "from_entropy")
        {
            out.push(Finding::new(
                &file.path,
                line.number,
                "det/unseeded-rng",
                "RNG constructed without a content-derived seed (content_seed/cell_seed/lane_seed)".to_string(),
            ));
        }

        // safety/panic-in-lib: panics in input-parsing library paths.
        if panic_scope {
            for (tok, what) in [
                (".unwrap()", "unwrap()"),
                (".expect(\"", "expect()"),
                ("panic!(", "panic!"),
            ] {
                if has_token(code, tok) {
                    out.push(Finding::new(
                        &file.path,
                        line.number,
                        "safety/panic-in-lib",
                        format!("{what} in an input-parsing path — return an error with context instead"),
                    ));
                }
            }
        }
    }

    // lint/bare-allow: malformed pragmas or pragmas without a reason.
    for p in &file.pragmas {
        if p.rule.is_empty() {
            out.push(Finding::new(
                &file.path,
                p.line,
                "lint/bare-allow",
                "malformed detlint pragma — expected `detlint: allow(<rule>) — <reason>`".to_string(),
            ));
        } else if !RULES.contains(&p.rule.as_str()) {
            out.push(Finding::new(
                &file.path,
                p.line,
                "lint/bare-allow",
                format!("detlint pragma names unknown rule `{}`", p.rule),
            ));
        } else if p.reason.is_empty() {
            out.push(Finding::new(
                &file.path,
                p.line,
                "lint/bare-allow",
                format!("detlint allow({}) without a written reason", p.rule),
            ));
        }
    }

    out.sort_by(|a, b| (a.line, a.rule.as_str()).cmp(&(b.line, b.rule.as_str())));
    out
}

/// Apply suppression pragmas: a finding is suppressed when a well-formed
/// pragma for its rule sits on the same line or the line directly above.
/// `lint/bare-allow` findings are never suppressible.
pub fn apply_suppressions(file: &SourceFile, findings: &mut [Finding]) {
    for f in findings.iter_mut() {
        if f.rule == "lint/bare-allow" {
            continue;
        }
        let hit = file.pragmas.iter().any(|p| {
            p.rule == f.rule
                && !p.reason.is_empty()
                && (p.line == f.line || p.line + 1 == f.line)
        });
        if hit {
            f.suppressed = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::lexer::scan;
    use super::*;

    fn lint(path: &str, src: &str) -> Vec<Finding> {
        let file = scan(path, src);
        let mut fs = run_rules(&file);
        apply_suppressions(&file, &mut fs);
        fs
    }

    #[test]
    fn fx_prefix_does_not_match_hashmap_token() {
        assert!(find_token("let m: FxHashMap<u32, u32> = x;", "HashMap").is_empty());
        assert_eq!(find_token("use std::collections::HashMap;", "HashMap").len(), 1);
    }

    #[test]
    fn binding_names_are_collected_through_qualified_paths() {
        let f = scan(
            "src/coordinator/x.rs",
            "let pos: std::collections::HashMap<u32, u32> = HashMap::new();\n",
        );
        let names = collect_hash_names(&f);
        assert!(names.contains("pos"), "{names:?}");
        assert!(!names.contains("collections"));
    }

    #[test]
    fn use_lines_collect_nothing() {
        let f = scan("src/coordinator/x.rs", "use std::collections::HashMap;\n");
        assert!(collect_hash_names(&f).is_empty());
    }

    #[test]
    fn iteration_is_flagged_lookup_is_not() {
        let src = "struct S { m: FxHashMap<u32, u32> }\nfn f(s: &S) { for v in s.m.values() { let _ = v; } }\nfn g(s: &S) -> Option<&u32> { s.m.get(&1) }\n";
        let fs = lint("src/coordinator/x.rs", src);
        assert_eq!(fs.iter().filter(|f| f.rule == "det/hashmap-iter").count(), 1);
    }

    #[test]
    fn for_in_over_field_is_flagged_but_method_receiver_is_not() {
        let src = "struct S { m: FxHashMap<u32, u32> }\nimpl S { fn f(&self) { for x in self.m.get(&1) { let _ = x; } } }\n";
        let fs = lint("src/coordinator/x.rs", src);
        assert!(fs.iter().all(|f| f.rule != "det/hashmap-iter"), "{fs:?}");
        let src2 = "fn f(m: &FxHashMap<u32, u32>) { for x in m { let _ = x; } }\n";
        let fs2 = lint("src/coordinator/x.rs", src2);
        assert_eq!(fs2.iter().filter(|f| f.rule == "det/hashmap-iter").count(), 1);
    }

    #[test]
    fn suppression_applies_to_own_and_next_line() {
        let src = "fn f(m: &FxHashMap<u32, u32>) {\n    // detlint: allow(det/hashmap-iter) — keys are sorted below\n    let mut ks: Vec<_> = m.keys().collect();\n    ks.sort();\n}\n";
        let fs = lint("src/coordinator/x.rs", src);
        let f = fs.iter().find(|f| f.rule == "det/hashmap-iter").unwrap();
        assert!(f.suppressed);
    }

    #[test]
    fn bare_allow_is_a_finding_and_does_not_suppress() {
        let src = "fn f(m: &FxHashMap<u32, u32>) {\n    // detlint: allow(det/hashmap-iter)\n    for k in m.keys() { let _ = k; }\n}\n";
        let fs = lint("src/coordinator/x.rs", src);
        assert!(fs.iter().any(|f| f.rule == "lint/bare-allow"));
        let f = fs.iter().find(|f| f.rule == "det/hashmap-iter").unwrap();
        assert!(!f.suppressed);
    }

    #[test]
    fn test_code_is_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(m: &FxHashMap<u32, u32>) { for k in m.keys() { let _ = k; } }\n}\n";
        assert!(lint("src/coordinator/x.rs", src).is_empty());
    }

    #[test]
    fn panic_scope_rules() {
        let src = "fn parse(s: &str) -> u32 { s.parse().unwrap() }\n";
        assert_eq!(lint("src/util/cli.rs", src).len(), 1);
        assert!(lint("src/coordinator/solver.rs", src).is_empty());
        // json.rs's own byte-level expect() helper must not match.
        let src2 = "fn f(p: &mut P) { p.expect(b'\"'); }\n";
        assert!(lint("src/util/json.rs", src2).is_empty());
    }

    #[test]
    fn wall_clock_exemptions() {
        let src = "let t = std::time::Instant::now();\n";
        assert_eq!(lint("src/coordinator/solver.rs", src).len(), 1);
        assert!(lint("src/bench.rs", src).is_empty());
        assert!(lint("src/main.rs", src).is_empty());
        assert!(lint("src/runtime/executor.rs", src).is_empty());
    }

    #[test]
    fn unseeded_rng_heuristic() {
        assert_eq!(lint("src/x.rs", "let r = Rng::new(12345);\n").len(), 1);
        assert!(lint("src/x.rs", "let r = Rng::new(cell_seed(&cell));\n").is_empty());
        assert!(lint("src/x.rs", "let r = Rng::new(self.seed);\n").is_empty());
    }

    #[test]
    fn checkpoint_hash_flags_std_hashers_in_coordinator_only() {
        let src = "use std::collections::hash_map::DefaultHasher;\nfn sig() -> u64 { let h = DefaultHasher::new(); h.finish() }\n";
        let fs = lint("src/coordinator/delta.rs", src);
        assert_eq!(fs.iter().filter(|f| f.rule == "det/checkpoint-hash").count(), 2, "{fs:?}");
        assert!(lint("src/util/x.rs", src).iter().all(|f| f.rule != "det/checkpoint-hash"));
        // the Fx shim itself never matches
        let clean = "use crate::util::fxhash::FxHasher;\nfn sig() -> u64 { let h = FxHasher::default(); h.finish() }\n";
        assert!(lint("src/coordinator/delta.rs", clean).is_empty());
        // RandomState (the HashMap default build-hasher) matches too
        let fs2 = lint("src/coordinator/x.rs", "fn f(s: RandomState) { let _ = s; }\n");
        assert_eq!(fs2.iter().filter(|f| f.rule == "det/checkpoint-hash").count(), 1);
    }

    #[test]
    fn partial_cmp_unwrap_is_flagged_in_coordinator_only() {
        let src = "fn f(v: &mut Vec<f64>) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n";
        let fs = lint("src/coordinator/trace.rs", src);
        assert_eq!(fs.iter().filter(|f| f.rule == "det/partial-cmp-unwrap").count(), 1, "{fs:?}");
        assert!(lint("src/util/x.rs", src).is_empty());
        // the fix idiom never matches
        let clean = "fn f(v: &mut Vec<f64>) { v.sort_by(|a, b| a.total_cmp(b)); }\n";
        assert!(lint("src/coordinator/trace.rs", clean).is_empty());
        // partial_cmp with graceful handling is fine
        let graceful = "fn f(a: f64, b: f64) -> Ordering { a.partial_cmp(&b).unwrap_or(Ordering::Equal) }\n";
        assert!(lint("src/coordinator/trace.rs", graceful).is_empty());
    }

    #[test]
    fn partial_cmp_unwrap_is_suppressible_with_reason() {
        let src = "fn f(v: &mut Vec<f64>) {\n    // detlint: allow(det/partial-cmp-unwrap) — inputs validated finite\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
        let fs = lint("src/coordinator/x.rs", src);
        let f = fs.iter().find(|f| f.rule == "det/partial-cmp-unwrap").unwrap();
        assert!(f.suppressed);
    }

    #[test]
    fn float_reduce_over_hash_iter() {
        let src = "struct S { m: FxHashMap<u32, f64> }\nimpl S { fn f(&self) -> f64 { self.m.values().sum() } }\n";
        let fs = lint("src/util/x.rs", src);
        assert_eq!(fs.iter().filter(|f| f.rule == "det/float-reduce").count(), 1);
    }
}
