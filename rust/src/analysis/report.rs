//! Deterministic rendering of lint findings.
//!
//! Both the human report and the `--json` report are byte-stable across
//! runs: findings are sorted by (file, line, rule), paths are normalized
//! to '/'-separated labels, JSON objects use the crate's BTreeMap-backed
//! [`crate::util::json::Json`] (sorted keys), and no timestamps or
//! absolute paths appear anywhere in the output.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// One lint finding.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Normalized '/'-separated path label, e.g. `src/coordinator/sweep.rs`.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule id, e.g. `det/hashmap-iter`.
    pub rule: String,
    pub message: String,
    /// True when silenced by a well-formed `detlint: allow` pragma.
    pub suppressed: bool,
}

impl Finding {
    pub fn new(file: &str, line: usize, rule: &str, message: String) -> Self {
        Finding { file: file.to_string(), line, rule: rule.to_string(), message, suppressed: false }
    }
}

/// The outcome of a lint run over a set of files.
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    /// All findings, suppressed ones included, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Number of files scanned.
    pub files_scanned: usize,
}

impl LintReport {
    pub fn sort(&mut self) {
        self.findings
            .sort_by(|a, b| (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule)));
    }

    pub fn unsuppressed(&self) -> usize {
        self.findings.iter().filter(|f| !f.suppressed).count()
    }

    pub fn suppressed(&self) -> usize {
        self.findings.iter().filter(|f| f.suppressed).count()
    }

    /// Human-readable report: one `file:line: rule: message` line per
    /// finding (suppressed ones annotated), then per-rule counts, then a
    /// one-line summary. Byte-stable for a given tree.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            if f.suppressed {
                out.push_str(&format!(
                    "{}:{}: {}: suppressed: {}\n",
                    f.file, f.line, f.rule, f.message
                ));
            } else {
                out.push_str(&format!("{}:{}: {}: {}\n", f.file, f.line, f.rule, f.message));
            }
        }
        let mut by_rule: BTreeMap<&str, (usize, usize)> = BTreeMap::new();
        for f in &self.findings {
            let e = by_rule.entry(f.rule.as_str()).or_insert((0, 0));
            if f.suppressed {
                e.1 += 1;
            } else {
                e.0 += 1;
            }
        }
        if !by_rule.is_empty() {
            out.push('\n');
            for (rule, (open, supp)) in &by_rule {
                out.push_str(&format!("  {rule}: {open} finding(s), {supp} suppressed\n"));
            }
        }
        out.push_str(&format!(
            "\ndetlint: {} file(s) scanned, {} finding(s), {} suppressed\n",
            self.files_scanned,
            self.unsuppressed(),
            self.suppressed()
        ));
        out
    }

    /// Canonical JSON report (sorted keys, sorted findings, no
    /// timestamps) — byte-identical across repeated runs on the same tree.
    pub fn to_json(&self) -> Json {
        let findings: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                let mut o = BTreeMap::new();
                o.insert("file".to_string(), Json::Str(f.file.clone()));
                o.insert("line".to_string(), Json::Num(f.line as f64));
                o.insert("rule".to_string(), Json::Str(f.rule.clone()));
                o.insert("message".to_string(), Json::Str(f.message.clone()));
                o.insert("suppressed".to_string(), Json::Bool(f.suppressed));
                Json::Obj(o)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("files_scanned".to_string(), Json::Num(self.files_scanned as f64));
        root.insert("findings".to_string(), Json::Arr(findings));
        root.insert("unsuppressed".to_string(), Json::Num(self.unsuppressed() as f64));
        root.insert("suppressed".to_string(), Json::Num(self.suppressed() as f64));
        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> LintReport {
        let mut r = LintReport { files_scanned: 2, ..Default::default() };
        r.findings.push(Finding::new("src/b.rs", 3, "det/wall-clock", "x".into()));
        let mut s = Finding::new("src/a.rs", 9, "det/unseeded-rng", "y".into());
        s.suppressed = true;
        r.findings.push(s);
        r.sort();
        r
    }

    #[test]
    fn findings_sort_by_file_line_rule() {
        let r = sample();
        assert_eq!(r.findings[0].file, "src/a.rs");
        assert_eq!(r.unsuppressed(), 1);
        assert_eq!(r.suppressed(), 1);
    }

    #[test]
    fn render_is_stable_and_mentions_counts() {
        let r = sample();
        let a = r.render();
        let b = r.render();
        assert_eq!(a, b);
        assert!(a.contains("src/b.rs:3: det/wall-clock: x"));
        assert!(a.contains("suppressed: y"));
        assert!(a.contains("2 file(s) scanned, 1 finding(s), 1 suppressed"));
    }

    #[test]
    fn json_is_byte_identical_across_renders() {
        let r = sample();
        assert_eq!(r.to_json().to_string(), r.to_json().to_string());
        let text = r.to_json().to_string();
        assert!(text.contains("\"files_scanned\":2"));
        assert!(text.contains("\"rule\":\"det/unseeded-rng\""));
    }
}
