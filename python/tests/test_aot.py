"""AOT path: lowered HLO text is well-formed and numerically faithful.

The Rust-side load/execute is covered by `cargo test` (runtime module); here
we prove the python side: HLO text round-trips through the local XLA client
and reproduces the oracle numbers, and the manifest metadata is consistent.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


@pytest.mark.parametrize("task,b", [("gemm", 32), ("syrk", 32), ("trsm", 32), ("potrf", 32), ("gemm", 64)])
def test_lowered_hlo_is_parseable(task, b):
    text = aot.lower_task(task, b, jnp.float32)
    assert "ENTRY" in text and "HloModule" in text
    # the ENTRY computation body declares one parameter per operand
    nargs = model.TASKS[task][1]
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    body = []
    for l in lines[start + 1 :]:
        if l.startswith("}"):
            break
        body.append(l)
    arity = sum("= f32" in l and "parameter(" in l or "= f64" in l and "parameter(" in l for l in body)
    assert arity == nargs, lines[start]
    # entry layout matches the operand count too
    layout = lines[0]
    assert layout.count("{1,0}") >= nargs + 1  # args + result


def test_roundtrip_numerics_via_jit():
    """Executing the *same lowered computation* via jax.jit equals oracle —
    guards against the tupling wrapper changing semantics."""
    b = 32
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.standard_normal((b, b)), jnp.float32)
    a = jnp.asarray(rng.standard_normal((b, b)), jnp.float32)
    bb = jnp.asarray(rng.standard_normal((b, b)), jnp.float32)

    fn, _ = model.TASKS["gemm"]
    out = jax.jit(lambda *xs: (fn(*xs),))(c, a, bb)[0]
    np.testing.assert_allclose(out, ref.gemm_ref(c, a, bb), rtol=3e-4, atol=3e-4)


def test_task_flops():
    assert aot.task_flops("potrf", 10) == pytest.approx(1000 / 3)
    assert aot.task_flops("trsm", 10) == 1000
    assert aot.task_flops("syrk", 10) == 1000
    assert aot.task_flops("gemm", 10) == 2000
    with pytest.raises(ValueError):
        aot.task_flops("nope", 10)


def test_manifest_written(tmp_path):
    import subprocess, sys

    out = tmp_path / "arts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--tiles", "32", "--dtypes", "f32", "--tasks", "gemm", "trsm"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    names = {e["name"] for e in manifest["entries"]}
    assert names == {"gemm_f32_32", "trsm_f32_32"}
    for e in manifest["entries"]:
        assert (out / e["file"]).exists()
        assert e["num_args"] == model.TASKS[e["task"]][1]
