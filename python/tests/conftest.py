import jax
import pytest

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session", autouse=True)
def _x64():
    # f64 kernels (ODROID experiments run double precision) need x64 mode.
    assert jax.config.jax_enable_x64
