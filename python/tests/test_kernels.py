"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (any multiple of the minimal block edge) and both
dtypes; explicit cases pin the tile edges the AOT artifacts ship.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import gemm as gemm_k
from compile.kernels import ref
from compile.kernels import trsm as trsm_k

DTYPES = [jnp.float32, jnp.float64]


def tol(dtype):
    return dict(rtol=3e-4, atol=3e-4) if dtype == jnp.float32 else dict(rtol=1e-9, atol=1e-9)


def rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype)


def rand_lower(rng, n, dtype):
    """Well-conditioned lower-triangular matrix."""
    return jnp.asarray(np.tril(rng.standard_normal((n, n))) + 4.0 * np.eye(n), dtype)


# ---------------------------------------------------------------- pick_block


@pytest.mark.parametrize(
    "dim,cap,expect",
    [(256, 128, 128), (96, 128, 32), (32, 128, 32), (8, 128, 8), (7, 128, 7 and 1), (1, 128, 1), (40, 8, 8), (48, 8, 8)],
)
def test_pick_block_divides(dim, cap, expect):
    b = gemm_k.pick_block(dim, cap)
    assert dim % b == 0 and b <= cap
    assert b == expect


@given(st.integers(1, 4096), st.sampled_from([8, 32, 128]))
def test_pick_block_always_legal(dim, cap):
    b = gemm_k.pick_block(dim, cap)
    assert 1 <= b <= cap and dim % b == 0


def test_pick_block_rejects_nonpositive():
    with pytest.raises(ValueError):
        gemm_k.pick_block(0)


# --------------------------------------------------------------------- GEMM


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("m,n,k", [(32, 32, 32), (64, 32, 96), (128, 128, 64), (256, 256, 256), (8, 8, 8)])
def test_gemm_matches_ref(dtype, m, n, k):
    rng = np.random.default_rng(m * 1000 + n * 10 + k)
    c, a, b = rand(rng, (m, n), dtype), rand(rng, (m, k), dtype), rand(rng, (n, k), dtype)
    np.testing.assert_allclose(gemm_k.gemm(c, a, b), ref.gemm_ref(c, a, b), **tol(dtype))


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([8, 16, 24, 40, 64, 96]),
    n=st.sampled_from([8, 16, 32, 48, 80]),
    k=st.sampled_from([8, 16, 32, 56, 72]),
    dtype=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**16),
)
def test_gemm_hypothesis(m, n, k, dtype, seed):
    rng = np.random.default_rng(seed)
    c, a, b = rand(rng, (m, n), dtype), rand(rng, (m, k), dtype), rand(rng, (n, k), dtype)
    np.testing.assert_allclose(gemm_k.gemm(c, a, b), ref.gemm_ref(c, a, b), **tol(dtype))


def test_gemm_explicit_blocks():
    rng = np.random.default_rng(7)
    c, a, b = rand(rng, (64, 64), jnp.float32), rand(rng, (64, 64), jnp.float32), rand(rng, (64, 64), jnp.float32)
    out = gemm_k.gemm(c, a, b, bm=16, bn=32, bk=64)
    np.testing.assert_allclose(out, ref.gemm_ref(c, a, b), **tol(jnp.float32))


def test_gemm_shape_mismatch_raises():
    z = jnp.zeros((8, 8), jnp.float32)
    with pytest.raises(ValueError):
        gemm_k.gemm(z, jnp.zeros((8, 4), jnp.float32), jnp.zeros((4, 4), jnp.float32))


def test_gemm_zero_update_is_identity():
    rng = np.random.default_rng(3)
    c = rand(rng, (32, 32), jnp.float64)
    a = jnp.zeros((32, 16), jnp.float64)
    b = rand(rng, (32, 16), jnp.float64)
    np.testing.assert_allclose(gemm_k.gemm(c, a, b), c)


# --------------------------------------------------------------------- SYRK


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n,k", [(32, 32), (64, 32), (128, 128), (96, 64)])
def test_syrk_matches_ref(dtype, n, k):
    rng = np.random.default_rng(n + k)
    c, a = rand(rng, (n, n), dtype), rand(rng, (n, k), dtype)
    np.testing.assert_allclose(gemm_k.syrk(c, a), ref.syrk_ref(c, a), **tol(dtype))


def test_syrk_preserves_symmetry():
    rng = np.random.default_rng(11)
    sym = rng.standard_normal((64, 64))
    c = jnp.asarray(sym + sym.T, jnp.float64)
    a = rand(rng, (64, 32), jnp.float64)
    out = gemm_k.syrk(c, a)
    np.testing.assert_allclose(out, out.T, rtol=1e-12, atol=1e-12)


def test_syrk_requires_square():
    with pytest.raises(ValueError):
        gemm_k.syrk(jnp.zeros((8, 16), jnp.float32), jnp.zeros((8, 8), jnp.float32))


# --------------------------------------------------------------------- TRSM


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("m,n", [(32, 32), (64, 32), (128, 64), (32, 128)])
def test_trsm_matches_ref(dtype, m, n):
    rng = np.random.default_rng(m + 7 * n)
    l, b = rand_lower(rng, n, dtype), rand(rng, (m, n), dtype)
    x = trsm_k.trsm(l, b)
    np.testing.assert_allclose(x, ref.trsm_ref(l, b), **tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
def test_trsm_residual(dtype):
    """Independent check: the solve satisfies X @ L^T = B."""
    rng = np.random.default_rng(42)
    l, b = rand_lower(rng, 64, dtype), rand(rng, (96, 64), dtype)
    x = trsm_k.trsm(l, b)
    np.testing.assert_allclose(x @ l.T, b, **tol(dtype))


@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([8, 16, 40, 64]),
    n=st.sampled_from([8, 16, 32, 64]),
    dtype=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**16),
)
def test_trsm_hypothesis(m, n, dtype, seed):
    rng = np.random.default_rng(seed)
    l, b = rand_lower(rng, n, dtype), rand(rng, (m, n), dtype)
    np.testing.assert_allclose(trsm_k.trsm(l, b) @ l.T, b, **tol(dtype))


def test_trsm_identity_l():
    rng = np.random.default_rng(5)
    b = rand(rng, (32, 32), jnp.float32)
    np.testing.assert_allclose(trsm_k.trsm(jnp.eye(32, dtype=jnp.float32), b), b, rtol=1e-6)


def test_trsm_shape_mismatch_raises():
    with pytest.raises(ValueError):
        trsm_k.trsm(jnp.zeros((8, 8), jnp.float32), jnp.zeros((8, 16), jnp.float32))


def test_inv_lower_small():
    rng = np.random.default_rng(9)
    l = rand_lower(rng, 8, jnp.float64)
    inv = trsm_k._inv_lower(l)
    np.testing.assert_allclose(inv @ l, np.eye(8), rtol=1e-10, atol=1e-10)
