"""L2 correctness: blocked POTRF and the full tiled Cholesky composition."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def tol(dtype):
    return dict(rtol=5e-4, atol=5e-4) if dtype == jnp.float32 else dict(rtol=1e-8, atol=1e-8)


@pytest.mark.parametrize("n", [4, 8, 16, 32])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_potrf_unblocked(n, dtype):
    a = model.random_spd(n, dtype, seed=n)
    l = model.potrf_unblocked(a)
    np.testing.assert_allclose(ref.cholesky_reconstruct(l), a, **tol(dtype))
    # strictly lower-triangular output
    np.testing.assert_allclose(np.triu(np.asarray(l), 1), 0.0)


@pytest.mark.parametrize("n", [32, 64, 128, 256])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_potrf_blocked(n, dtype):
    a = model.random_spd(n, dtype, seed=n + 1)
    l = model.potrf(a)
    np.testing.assert_allclose(ref.cholesky_reconstruct(l), a, **tol(dtype))
    np.testing.assert_allclose(np.triu(np.asarray(l), 1), 0.0)


def test_potrf_matches_oracle_factor():
    """Cholesky factors are unique (positive diagonal) — compare directly."""
    a = model.random_spd(64, jnp.float64, seed=3)
    np.testing.assert_allclose(model.potrf(a), ref.potrf_ref(a), rtol=1e-8, atol=1e-8)


def test_potrf_rejects_non_multiple():
    with pytest.raises(ValueError):
        model.potrf(jnp.eye(48, dtype=jnp.float32))  # 48 % 32 != 0


@pytest.mark.parametrize("s", [1, 2, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_cholesky_blocked(s, dtype):
    n = 64 * s
    a = model.random_spd(n, dtype, seed=s)
    l = model.cholesky_blocked(a, s)
    np.testing.assert_allclose(ref.cholesky_reconstruct(l), a, **tol(dtype))


@settings(max_examples=8, deadline=None)
@given(s=st.sampled_from([1, 2, 3]), b=st.sampled_from([32, 64]), seed=st.integers(0, 1000))
def test_cholesky_blocked_hypothesis(s, b, seed):
    a = model.random_spd(s * b, jnp.float64, seed=seed)
    l = model.cholesky_blocked(a, s)
    np.testing.assert_allclose(ref.cholesky_reconstruct(l), a, rtol=1e-8, atol=1e-8)


def test_cholesky_blocked_rejects_indivisible():
    with pytest.raises(ValueError):
        model.cholesky_blocked(jnp.eye(65, dtype=jnp.float32), 2)


def test_random_spd_is_spd():
    a = model.random_spd(96, jnp.float64, seed=0)
    np.testing.assert_allclose(a, a.T)
    w = np.linalg.eigvalsh(np.asarray(a))
    assert w.min() > 0
