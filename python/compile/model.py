"""L2: the tile-task compute graphs of blocked Cholesky, composing L1 kernels.

Each tile task HeSP schedules (POTRF / TRSM / SYRK / GEMM over a b x b tile)
is a jax function here; ``aot.py`` lowers one HLO module per (task, b, dtype)
and the Rust runtime (rust/src/runtime) executes them on the PJRT CPU client.

POTRF is a blocked right-looking factorization composing the Pallas
GEMM/SYRK/TRSM kernels with a small vectorized unblocked base case — written
in pure jnp index ops (NOT ``jnp.linalg.cholesky``, which lowers to a LAPACK
custom-call on CPU that the xla_extension 0.5.1 runtime cannot resolve).
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import gemm as gemm_k
from .kernels import trsm as trsm_k

# Unblocked base-case edge for the blocked POTRF. 32 keeps trace size small
# (one fused column update per iteration) while the Pallas kernels do the
# O(b^3) panel work above it.
POTRF_BASE = 32


def potrf_unblocked(a):
    """Lower Cholesky factor by right-looking column updates (pure jnp).

    One (static) iteration per column; each iteration is a rank-1 trailing
    update, so the lowered HLO is a flat chain of fused vector ops.
    """
    n = a.shape[0]
    rows = jnp.arange(n)
    l = jnp.zeros_like(a)
    for j in range(n):
        d = jnp.sqrt(a[j, j])
        col = jnp.where(rows > j, a[:, j] / d, jnp.zeros((), a.dtype)).at[j].set(d)
        l = l.at[:, j].set(col)
        a = a - jnp.outer(col, col)
    return l


def potrf(a, base: int = POTRF_BASE):
    """Blocked right-looking Cholesky of one b x b tile.

    for k-panels of edge ``base``:
      L_kk   = potrf_unblocked(A_kk)
      L_pk   = TRSM(L_kk, A_pk)            (Pallas, row-panel parallel)
      A_tail = SYRK(A_tail, L_pk)          (Pallas, grid-tiled)
    """
    n = a.shape[0]
    if n <= base:
        return potrf_unblocked(a)
    if n % base != 0:
        raise ValueError(f"tile edge {n} not a multiple of base {base}")
    l = jnp.zeros_like(a)
    for k in range(n // base):
        lo, hi = k * base, (k + 1) * base
        lkk = potrf_unblocked(a[lo:hi, lo:hi])
        l = l.at[lo:hi, lo:hi].set(lkk)
        if hi < n:
            panel = trsm_k.trsm(lkk, a[hi:, lo:hi])
            l = l.at[hi:, lo:hi].set(panel)
            a = a.at[hi:, hi:].set(gemm_k.syrk(a[hi:, hi:], panel))
    return jnp.tril(l)


def trsm(l, b):
    """TRSM tile task: X @ L^T = B (off-diagonal panel of the factorization)."""
    return trsm_k.trsm(l, b)


def syrk(c, a):
    """SYRK tile task: C - A @ A^T (diagonal trailing update)."""
    return gemm_k.syrk(c, a)


def gemm(c, a, b):
    """GEMM tile task: C - A @ B^T (off-diagonal trailing update)."""
    return gemm_k.gemm(c, a, b)


TASKS = {
    # name -> (fn, number of b x b operands)
    "potrf": (potrf, 1),
    "trsm": (trsm, 2),
    "syrk": (syrk, 2),
    "gemm": (gemm, 3),
}


def cholesky_blocked(a, s: int):
    """Full tiled Cholesky over an s x s grid of tiles — the same task
    sequence the Rust executor replays, used by pytest to prove the four
    tile tasks compose to a correct factorization."""
    n = a.shape[0]
    if n % s != 0:
        raise ValueError(f"matrix edge {n} not divisible by s={s}")
    b = n // s
    t = [[a[i * b : (i + 1) * b, j * b : (j + 1) * b] for j in range(s)] for i in range(s)]
    for k in range(s):
        t[k][k] = potrf(t[k][k])
        for i in range(k + 1, s):
            t[i][k] = trsm(t[k][k], t[i][k])
        for i in range(k + 1, s):
            t[i][i] = syrk(t[i][i], t[i][k])
            for j in range(k + 1, i):
                t[i][j] = gemm(t[i][j], t[i][k], t[j][k])
    out = jnp.zeros_like(a)
    for i in range(s):
        for j in range(i + 1):
            out = out.at[i * b : (i + 1) * b, j * b : (j + 1) * b].set(t[i][j])
    return out


def random_spd(n: int, dtype=jnp.float32, seed: int = 0):
    """Well-conditioned random SPD test matrix: G G^T / n + I."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (n, n), dtype=jnp.float32)
    a = (g @ g.T) / n + jnp.eye(n, dtype=jnp.float32) * 2.0
    return a.astype(dtype)
