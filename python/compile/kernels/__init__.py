"""L1 Pallas kernels for the dense linear-algebra tile tasks HeSP schedules.

The paper's driving workload is the blocked Cholesky factorization, whose
tile-level tasks are POTRF, TRSM, SYRK and GEMM. The throughput hot spot is
the trailing-matrix update (GEMM/SYRK: O(s^3) tasks vs O(s) POTRFs), so those
are grid-tiled Pallas kernels; TRSM is a row-panel-parallel Pallas kernel;
POTRF is composed at L2 (``compile.model``) from these kernels in a blocked
right-looking scheme with a small unblocked base case.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO that the Rust
runtime loads. Correctness is pinned against the pure-jnp oracles in
``ref.py`` (pytest + hypothesis-style sweeps in ``python/tests``).
"""

from . import gemm, trsm, ref  # noqa: F401
