"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Deliberately written on a *different* code path (jnp matmul / jnp triangular
inverse / jnp cholesky) so a kernel bug cannot cancel against an oracle bug.
"""

import jax.numpy as jnp


def gemm_ref(c, a, b):
    """C - A @ B^T."""
    return c - a @ b.T


def syrk_ref(c, a):
    """C - A @ A^T."""
    return c - a @ a.T


def trsm_ref(l, b):
    """X with X @ L^T = B, via an explicit triangular inverse."""
    n = l.shape[0]
    eye = jnp.eye(n, dtype=l.dtype)
    # jnp.linalg.solve on the triangular system (dense solve — independent
    # of the kernel's substitution path).
    linv = jnp.linalg.solve(l, eye)
    return b @ linv.T


def potrf_ref(a):
    """Lower Cholesky factor of SPD matrix A."""
    return jnp.linalg.cholesky(a)


def cholesky_reconstruct(l):
    """A = L @ L^T (round-trip check)."""
    return l @ l.T
