"""Pallas GEMM / SYRK tile kernels: the flops hot spot of blocked Cholesky.

Computes the trailing-update form used by the factorization,

    C <- C - A @ B^T        (GEMM:  A_ij -= A_ik @ A_jk^T)
    C <- C - A @ A^T        (SYRK:  A_ii -= A_ik @ A_ik^T)

as a grid-tiled Pallas kernel. The grid is (m/bm, n/bn, k/bk); the k axis is
the innermost (sequential) accumulation axis, so each (i, j) output block is
initialized from C on the first k-step and accumulated in place afterwards —
the standard Pallas matmul schedule, expressing the HBM<->VMEM pipeline the
paper's CUDA kernels express with threadblocks (DESIGN.md
§Hardware-Adaptation).

VMEM footprint per step is bm*bn + bm*bk + bn*bk elements (3 * 128^2 * 4 B
= 192 KiB at the default block, comfortably under a TPU core's ~16 MiB
VMEM and leaving room for double-buffering).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Candidate inner block edges, largest first. 128 is MXU-friendly (the
# systolic array is 128x128); smaller edges keep odd tile sizes legal.
_BLOCK_CANDIDATES = (128, 64, 32, 16, 8, 4, 2, 1)


def pick_block(dim: int, cap: int = 128) -> int:
    """Largest candidate block edge that divides ``dim`` (and is <= cap)."""
    if dim <= 0:
        raise ValueError(f"dimension must be positive, got {dim}")
    for b in _BLOCK_CANDIDATES:
        if b <= cap and dim % b == 0:
            return b
    return 1


def _gemm_kernel(c_ref, a_ref, b_ref, o_ref):
    """One (bm, bn) output block; k-steps accumulate sequentially."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = c_ref[...]

    # fp32/fp64 accumulate on the MXU; B is stored (n, k) so the update is
    # an explicit outer-product-panel contraction A(bm,bk) @ B(bn,bk)^T.
    o_ref[...] -= jax.lax.dot_general(
        a_ref[...],
        b_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=o_ref.dtype,
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm(c, a, b, *, bm: int | None = None, bn: int | None = None, bk: int | None = None):
    """C - A @ B^T with C:(m,n), A:(m,k), B:(n,k) — Pallas, interpret mode."""
    m, n = c.shape
    k = a.shape[1]
    if a.shape != (m, k) or b.shape != (n, k):
        raise ValueError(f"shape mismatch: C{c.shape} A{a.shape} B{b.shape}")
    bm = bm or pick_block(m)
    bn = bn or pick_block(n)
    bk = bk or pick_block(k)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bn, bk), lambda i, j, l: (j, l)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        interpret=True,
    )(c, a, b)


def syrk(c, a, **kw):
    """C - A @ A^T (symmetric rank-k trailing update of a diagonal tile).

    Reuses the GEMM kernel with both panel operands bound to A; the full
    (not just lower-triangular) block is updated, which keeps diagonal
    tiles exactly symmetric — the factorization only ever reads the lower
    triangle, so this is numerically equivalent to a masked SYRK.
    """
    if c.shape[0] != c.shape[1]:
        raise ValueError(f"SYRK output must be square, got {c.shape}")
    return gemm(c, a, a, **kw)
