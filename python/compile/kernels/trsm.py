"""Pallas TRSM tile kernel: X @ L^T = B  =>  X = B @ L^-T.

This is the ``A_ik <- A_ik * L_kk^-T`` panel solve of blocked Cholesky.
Rows of B are independent in X L^T = B (each row solves x_i L^T = b_i), so
the Pallas grid parallelizes over (bm, n) row panels while the triangular
matrix L stays resident — the natural TPU mapping of the row-blocked cuBLAS
TRSM the paper's platforms would use.

Within a panel the solve is a blocked forward substitution over column
blocks of L (block edge ``bj``): the diagonal block is inverted by an
unrolled unit-step substitution (pure mul/add — MXU/VPU friendly, no
data-dependent control flow), and off-diagonal contributions are folded in
with dot products.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gemm import pick_block


def _inv_lower(l):
    """Inverse of a small lower-triangular block by forward substitution.

    Unrolled over the (static) block edge; produces pure mul/add ops that
    lower to plain HLO in interpret mode.
    """
    n = l.shape[0]
    inv = jnp.zeros_like(l)
    for i in range(n):
        e = jnp.zeros((n,), l.dtype).at[i].set(1.0)
        # solve L y = e_i by forward substitution
        y = jnp.zeros((n,), l.dtype)
        for r in range(n):
            s = e[r] - jnp.dot(l[r, :], y)
            y = y.at[r].set(s / l[r, r])
        inv = inv.at[:, i].set(y)
    return inv


def _trsm_kernel(l_ref, b_ref, o_ref, *, bj: int, nj: int):
    """Solve X L^T = B for one (bm, n) row panel of B.

    Column-block forward substitution:
      X_j = (B_j - sum_{p<j} X_p L_jp^T) L_jj^-T
    """
    l = l_ref[...]
    b = b_ref[...]
    xs = []  # solved column blocks, in order
    for j in range(nj):
        lo = j * bj
        rhs = b[:, lo : lo + bj]
        for p in range(j):
            po = p * bj
            ljp = l[lo : lo + bj, po : po + bj]
            rhs = rhs - jax.lax.dot_general(
                xs[p],
                ljp,
                dimension_numbers=(((1,), (1,)), ((), ())),
                preferred_element_type=b.dtype,
            )
        ljj = l[lo : lo + bj, lo : lo + bj]
        inv = _inv_lower(ljj)
        # X_j = rhs @ L_jj^-T
        xs.append(
            jax.lax.dot_general(
                rhs,
                inv,
                dimension_numbers=(((1,), (1,)), ((), ())),
                preferred_element_type=b.dtype,
            )
        )
    o_ref[...] = xs[0] if nj == 1 else jnp.concatenate(xs, axis=1)


@functools.partial(jax.jit, static_argnames=("bm", "bj"))
def trsm(l, b, *, bm: int | None = None, bj: int | None = None):
    """X such that X @ L^T = B; L:(n,n) lower-triangular, B:(m,n)."""
    m, n = b.shape
    if l.shape != (n, n):
        raise ValueError(f"shape mismatch: L{l.shape} B{b.shape}")
    bm = bm or pick_block(m)
    # diagonal-block edge: unrolled substitution is O(bj^3) python ops at
    # trace time, keep it small.
    bj = bj or pick_block(n, cap=8)
    nj = n // bj
    grid = (m // bm,)
    kernel = functools.partial(_trsm_kernel, bj=bj, nj=nj)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), b.dtype),
        interpret=True,
    )(l, b)
