"""Build-time Python for HeSP: JAX/Pallas kernel authoring + AOT lowering.

Nothing in this package is imported at runtime; ``make artifacts`` runs
``compile.aot`` once and the Rust binary consumes only ``artifacts/``.
"""
