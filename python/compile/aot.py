"""AOT lowering: one HLO-text module per (tile task, tile edge, dtype).

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Emits ``<task>_<dtype>_<b>.hlo.txt`` plus ``manifest.json`` describing every
artifact (task, dtype, tile edge, operand count, flops) for the Rust runtime.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Tile edges the Rust executor can schedule at. Must be multiples of
# model.POTRF_BASE (32) so the blocked POTRF tiles evenly.
DEFAULT_TILES = (32, 64, 128, 256)
DTYPES = {"f32": jnp.float32, "f64": jnp.float64}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def task_flops(task: str, b: int) -> float:
    """Standard flop counts for b x b tile tasks (single tile, lower-Cholesky
    convention; matches rust/src/coordinator/task.rs)."""
    if task == "potrf":
        return b**3 / 3.0
    if task == "trsm":
        return float(b**3)
    if task == "syrk":
        return float(b**3)  # full-block symmetric update (see kernels.gemm.syrk)
    if task == "gemm":
        return 2.0 * b**3
    raise ValueError(task)


def lower_task(task: str, b: int, dtype) -> str:
    fn, nargs = model.TASKS[task]
    spec = jax.ShapeDtypeStruct((b, b), dtype)

    def tupled(*args):
        return (fn(*args),)

    lowered = jax.jit(tupled).lower(*([spec] * nargs))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--tiles", type=int, nargs="*", default=list(DEFAULT_TILES))
    ap.add_argument("--dtypes", nargs="*", default=["f32", "f64"])
    ap.add_argument("--tasks", nargs="*", default=list(model.TASKS))
    args = ap.parse_args()

    jax.config.update("jax_enable_x64", True)
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "entries": []}
    for dt_name in args.dtypes:
        dtype = DTYPES[dt_name]
        for b in args.tiles:
            for task in args.tasks:
                name = f"{task}_{dt_name}_{b}"
                path = os.path.join(args.out_dir, f"{name}.hlo.txt")
                text = lower_task(task, b, dtype)
                with open(path, "w") as f:
                    f.write(text)
                manifest["entries"].append(
                    {
                        "name": name,
                        "file": f"{name}.hlo.txt",
                        "task": task,
                        "dtype": dt_name,
                        "tile": b,
                        "num_args": model.TASKS[task][1],
                        "flops": task_flops(task, b),
                    }
                )
                print(f"lowered {name}: {len(text)} chars")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['entries'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
